"""JAX implementation of the fleet-engine kernels (`jax.jit` + `vmap`).

Same signatures, same semantics as
:mod:`repro.core.engine_backend.numpy_backend` — NumPy arrays in, NumPy
arrays out — with the array math dispatched through XLA:

* row-wise binary search is ``vmap(jnp.searchsorted)``;
* the logarithmic-filter recurrence ``y_{i+1} = a_i·y_i + b_i`` (affine
  per segment) runs as a ``lax.associative_scan`` over segments —
  O(log S) depth instead of the NumPy backend's sequential Python loop;
* the poll-counting closed form is one fused jitted kernel.

Everything is traced under ``jax.experimental.enable_x64`` so float64
semantics match NumPy bit-for-bit on elementwise arithmetic; only
reduction/scan association order differs, which is why the parity
contract is "within one reporting quantum", not bitwise
(``tests/test_engine_backend.py`` pins it).  Compiled kernels are cached
by shape, so repeated trials of a fixed fleet re-use one compilation.
"""
from __future__ import annotations

import functools
from typing import Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import enable_x64

from repro.core.engine_backend.pytrees import (PollGrid, ReadingSchedule,
                                               TimelineArrays)

name = "jax"

_FAR = np.iinfo(np.int64).max // 2


def _searchsorted_rows(a, v, side: str):
    g = v.shape[0]
    if a.shape[0] == 1 and g > 1:
        a = jnp.broadcast_to(a, (g, a.shape[1]))
    return jax.vmap(
        lambda ar, vr: jnp.searchsorted(ar, vr, side=side))(a, v)


def _broadcast_rows(tl: TimelineArrays, g: int) -> TimelineArrays:
    r = tl.edges.shape[0]
    if r == g:
        return tl
    if r != 1:
        raise ValueError(f"{g} query rows for {r} timeline rows")
    return TimelineArrays(
        jnp.broadcast_to(tl.edges, (g, tl.edges.shape[1])),
        jnp.broadcast_to(tl.powers, (g, tl.powers.shape[1])),
        jnp.broadcast_to(tl.idle_w, (g,)),
        jnp.broadcast_to(tl.n_segs, (g,)))


@jax.jit
def _integral_impl(tl: TimelineArrays, t0, t1):
    g = t0.shape[0]
    seg = tl.powers * jnp.diff(tl.edges, axis=1)
    cum = jnp.concatenate(
        [jnp.zeros((tl.edges.shape[0], 1)), jnp.cumsum(seg, axis=1)],
        axis=1)
    tl = _broadcast_rows(tl, g)
    cum = jnp.broadcast_to(cum, (g, cum.shape[1]))
    e, p, idle, ns = tl
    first = e[:, 0][:, None]
    last = e[:, -1][:, None]
    hi_idx = jnp.maximum(ns - 1, 0)[:, None]

    def eval_I(t):
        tc = jnp.clip(t, first, last)
        idx = jnp.clip(_searchsorted_rows(e, tc, "right") - 1, 0, hi_idx)
        inner = (jnp.take_along_axis(cum, idx, axis=1)
                 + jnp.take_along_axis(p, idx, axis=1)
                 * (tc - jnp.take_along_axis(e, idx, axis=1)))
        before = jnp.minimum(t - first, 0.0) * idle[:, None]
        after = jnp.maximum(t - last, 0.0) * idle[:, None]
        return inner + before + after

    return eval_I(t1) - eval_I(t0)


@jax.jit
def _boxcar_impl(tl: TimelineArrays, t0, t1):
    dt = jnp.maximum(t1 - t0, 1e-12)
    return _integral_impl(tl, t0, t1) / dt


@jax.jit
def _estimation_impl(tl: TimelineArrays, t0, t1, model_gain):
    return _boxcar_impl(tl, t0, t1) * model_gain[:, None]


@jax.jit
def _log_filter_impl(tl: TimelineArrays, ticks, tau, t_lo, t_hi):
    g = ticks.shape[0]
    r = tl.edges.shape[0]
    ext_e = jnp.concatenate([jnp.full((r, 1), t_lo), tl.edges,
                             jnp.full((r, 1), t_hi)], axis=1)
    ext_p = jnp.concatenate([tl.idle_w[:, None], tl.powers,
                             tl.idle_w[:, None]], axis=1)
    n_seg = ext_p.shape[1]
    dts = jnp.broadcast_to(jnp.diff(ext_e, axis=1), (g, n_seg))
    sp = jnp.broadcast_to(ext_p, (g, n_seg))
    # each segment advances the filter state affinely:
    #   y_{i+1} = a_i · y_i + b_i  with  a_i = e^{-dt_i/tau},
    #   b_i = P_i (1 - a_i); zero-width padding steps are the identity map
    decay = jnp.exp(-dts / tau[:, None])
    a_seg = jnp.where(dts > 0, decay, 1.0)
    b_seg = jnp.where(dts > 0, sp * (1.0 - decay), 0.0)

    def compose(lo, hi):
        a1, b1 = lo
        a2, b2 = hi
        return (a1 * a2, b1 * a2 + b2)

    A, B = lax.associative_scan(compose, (a_seg, b_seg), axis=1)
    y0 = jnp.broadcast_to(tl.idle_w, (g,))[:, None]
    y = jnp.concatenate([y0, A * y0 + B], axis=1)          # [g, n_seg+1]

    ext_e_g = jnp.broadcast_to(ext_e, (g, n_seg + 1))
    idx = jnp.clip(_searchsorted_rows(ext_e, ticks, "right") - 1,
                   0, n_seg - 1)
    y_at = jnp.take_along_axis(y, idx, axis=1)
    sp_at = jnp.take_along_axis(sp, idx, axis=1)
    e_at = jnp.take_along_axis(ext_e_g, idx, axis=1)
    return sp_at + (y_at - sp_at) * jnp.exp(-(ticks - e_at) / tau[:, None])


@jax.jit
def _query_slots_impl(sched: ReadingSchedule, tq):
    T = sched.update_period_s[:, None]
    phase = sched.phase[:, None]
    m = sched.ticks.shape[1]
    j = jnp.floor((tq - phase) / T).astype(jnp.int64) - sched.k0[:, None]
    j = jnp.clip(j, 0, m - 1)
    for _ in range(2):
        tj = jnp.take_along_axis(sched.ticks, j, axis=1)
        j = jnp.where((tj > tq) & (j > 0), j - 1, j)
    for _ in range(2):
        jn = jnp.minimum(j + 1, m - 1)
        tn = jnp.take_along_axis(sched.ticks, jn, axis=1)
        j = jnp.where((tn <= tq) & (jn > j), jn, j)
    return jnp.clip(j, sched.first[:, None], sched.last[:, None])


@jax.jit
def _poll_counts_impl(sched: ReadingSchedule, t0, t1, period_s,
                      grid_offset, a, b):
    n = a.shape[0]
    m_i = jnp.floor((t1 - t0) / period_s).astype(jnp.int64)

    def q(idx):
        return t0 + period_s * idx

    def r(idx):
        return (t0 + period_s * idx) + grid_offset

    j0 = jnp.ceil((a - grid_offset - t0) / period_s).astype(jnp.int64)
    j1 = jnp.floor((b - grid_offset - t0) / period_s).astype(jnp.int64)
    for _ in range(2):
        j0 = jnp.where(r(j0 - 1) >= a, j0 - 1, j0)
        j0 = jnp.where(r(j0) < a, j0 + 1, j0)
        j1 = jnp.where(r(j1 + 1) <= b, j1 + 1, j1)
        j1 = jnp.where(r(j1) > b, j1 - 1, j1)
    j0 = jnp.maximum(j0, 0)
    j1 = jnp.minimum(j1, m_i - 1)

    ticks = sched.ticks
    m = ticks.shape[1]
    slot = jnp.arange(m)[None, :]
    lo = jnp.ceil((ticks - t0) / period_s).astype(jnp.int64)
    for _ in range(2):
        lo = jnp.where(q(lo - 1) >= ticks, lo - 1, lo)
        lo = jnp.where(q(lo) < ticks, lo + 1, lo)
    hi = jnp.concatenate([lo[:, 1:] - 1, jnp.full((n, 1), _FAR)], axis=1)
    lo = jnp.where(slot == sched.first[:, None], jnp.int64(0), lo)
    hi = jnp.where(slot == sched.last[:, None], _FAR, hi)
    counts = (jnp.minimum(hi, (j1 - 1)[:, None])
              - jnp.maximum(lo, j0[:, None]) + 1)
    valid = ((slot >= sched.first[:, None])
             & (slot <= sched.last[:, None]))
    counts = jnp.where(valid, jnp.maximum(counts, 0), 0)

    slot_b = _query_slots_impl(sched, q(j1.astype(jnp.float64))[:, None])
    tail_dt = b - r(j1.astype(jnp.float64))
    return counts, slot_b[:, 0], tail_dt, j1 >= j0


# -- public wrappers: NumPy in, NumPy out -----------------------------------

def boxcar_means(tl: TimelineArrays, t0: np.ndarray,
                 t1: np.ndarray) -> np.ndarray:
    with enable_x64():
        return np.asarray(_boxcar_impl(tl, jnp.asarray(t0, jnp.float64),
                                       jnp.asarray(t1, jnp.float64)))


def estimation_means(tl: TimelineArrays, t0: np.ndarray, t1: np.ndarray,
                     model_gain: np.ndarray) -> np.ndarray:
    with enable_x64():
        return np.asarray(_estimation_impl(
            tl, jnp.asarray(t0, jnp.float64), jnp.asarray(t1, jnp.float64),
            jnp.asarray(model_gain, jnp.float64)))


def timeline_integral(tl: TimelineArrays, t0: np.ndarray,
                      t1: np.ndarray) -> np.ndarray:
    with enable_x64():
        return np.asarray(_integral_impl(tl, jnp.asarray(t0, jnp.float64),
                                         jnp.asarray(t1, jnp.float64)))


def log_filter(tl: TimelineArrays, ticks: np.ndarray,
               tau: np.ndarray) -> np.ndarray:
    tau = np.asarray(tau, dtype=np.float64)
    # concrete pad bounds (cheap NumPy reductions) keep the jitted kernel
    # free of host round-trips; they only need to cover idle
    t_lo = (min(float(np.min(ticks)), float(np.min(tl.t_start)))
            - 5.0 * float(np.max(tau)))
    t_hi = max(float(np.max(ticks)), float(np.max(tl.t_end))) + 1e-9
    with enable_x64():
        return np.asarray(_log_filter_impl(
            tl, jnp.asarray(ticks, jnp.float64), jnp.asarray(tau),
            jnp.float64(t_lo), jnp.float64(t_hi)))


def poll_counts(sched: ReadingSchedule, grid: PollGrid, a: np.ndarray,
                b: np.ndarray) -> Tuple[np.ndarray, np.ndarray,
                                        np.ndarray, np.ndarray]:
    with enable_x64():
        counts, slot_b, tail_dt, nonempty = _poll_counts_impl(
            sched, jnp.float64(grid.t0),
            jnp.asarray(grid.t1, jnp.float64),
            jnp.float64(grid.period_s),
            jnp.asarray(grid.grid_offset, jnp.float64),
            jnp.asarray(a, jnp.float64), jnp.asarray(b, jnp.float64))
    return (np.asarray(counts), np.asarray(slot_b),
            np.asarray(tail_dt), np.asarray(nonempty))


def query_slots(sched: ReadingSchedule, tq: np.ndarray) -> np.ndarray:
    with enable_x64():
        return np.asarray(_query_slots_impl(
            sched, jnp.asarray(tq, jnp.float64)))


@functools.partial(jax.jit, static_argnums=(4,))
def _step_integrate_impl(ts, vals, t0, t1, trapezoid: bool):
    n, m = ts.shape
    j0 = _searchsorted_rows(ts, t0[:, None], "left")[:, 0]
    j1 = _searchsorted_rows(ts, t1[:, None], "right")[:, 0] - 1

    nxt_finite = jnp.isfinite(ts[:, 1:])
    dt = jnp.where(nxt_finite, ts[:, 1:] - ts[:, :-1], 0.0)
    if trapezoid:
        dens = 0.5 * (vals[:, :-1]
                      + jnp.where(nxt_finite, vals[:, 1:], 0.0))
    else:
        dens = vals[:, :-1]
    cum = jnp.concatenate(
        [jnp.zeros((n, 1)), jnp.cumsum(dens * dt, axis=1)], axis=1)

    j0c = jnp.clip(j0, 0, m - 1)[:, None]
    j1c = jnp.clip(j1, 0, m - 1)[:, None]
    core = (jnp.take_along_axis(cum, j1c, axis=1)
            - jnp.take_along_axis(cum, j0c, axis=1))[:, 0]
    tail = (jnp.take_along_axis(vals, j1c, axis=1)[:, 0]
            * (t1 - jnp.take_along_axis(ts, j1c, axis=1)[:, 0]))
    nonempty = (j1 >= j0) & (j0 < m)
    return jnp.where(nonempty, core + tail, 0.0)


def step_integrate(ts: np.ndarray, vals: np.ndarray, t0: np.ndarray,
                   t1: np.ndarray, trapezoid: bool = False) -> np.ndarray:
    """Batched rectangle/trapezoid step integration (see the numpy
    backend's reference docstring) as one jitted kernel."""
    ts = np.asarray(ts, dtype=np.float64)
    if ts.shape[1] == 0:    # no samples at all: every window is 0
        return np.zeros(ts.shape[0])
    with enable_x64():
        return np.asarray(_step_integrate_impl(
            jnp.asarray(ts, jnp.float64), jnp.asarray(vals, jnp.float64),
            jnp.asarray(t0, jnp.float64), jnp.asarray(t1, jnp.float64),
            bool(trapezoid)))


@functools.partial(jax.jit, static_argnums=(19,))
def _stream_ingest_impl(t, v, seg, first, start_idx, end_idx, prev_t,
                        prev_v, has_prev, run_t, n_changes, gain, offset,
                        tshift, win_a, win_b, max_hold, env_lo, env_hi,
                        trapezoid: bool):
    k = t.shape[0]
    u = prev_t.shape[0]
    idx = jnp.arange(k)

    shift_t = jnp.concatenate([jnp.zeros(1), t[:-1]])
    shift_v = jnp.concatenate([jnp.zeros(1), v[:-1]])
    pt = jnp.where(first, prev_t[seg], shift_t)
    pv = jnp.where(first, prev_v[seg], shift_v)
    has = jnp.where(first, has_prev[seg], True)

    g = gain[seg]
    off = offset[seg]
    vc = (v - off) / g
    pvc = (pv - off) / g
    dt = t - pt
    hold = jnp.minimum(dt, max_hold[seg])
    dens_r = 0.5 * (pv + v) if trapezoid else pv
    dens_c = 0.5 * (pvc + vc) if trapezoid else pvc
    inc = jnp.where(has, dens_r * hold, 0.0)
    inc_c = jnp.where(has, dens_c * hold, 0.0)

    cs = jnp.cumsum(inc)
    cum_e = cs - (cs[start_idx] - inc[start_idx])[seg]
    csc = jnp.cumsum(inc_c)
    cum_ec = csc - (csc[start_idx] - inc_c[start_idx])[seg]
    d_energy = cum_e[end_idx]
    d_energy_corr = cum_ec[end_idx]

    a = win_a[seg]
    b = win_b[seg]
    w_inc = jnp.where(
        has & (pt >= a),
        dens_r * jnp.maximum(jnp.minimum(pt + hold, b) - pt, 0.0), 0.0)
    pts = pt - tshift[seg]
    w_inc_c = jnp.where(
        has & (pts >= a),
        dens_c * jnp.maximum(jnp.minimum(pts + hold, b) - pts, 0.0), 0.0)
    d_win = jax.ops.segment_sum(w_inc, seg, num_segments=u)
    d_win_corr = jax.ops.segment_sum(w_inc_c, seg, num_segments=u)

    # run tracking without a log-depth ``lax.cummax`` rescan of the slab:
    # the previous change is found by ordinal arithmetic — scatter each
    # change's (position, time) at its 1-based change ordinal, then the
    # change before sample i sits at ordinal ``changes-strictly-before-i``
    # (slot 0 reads the -1/unused sentinel when there is none).  The
    # pre-slab maximum is carried in the monitor state (``run_t``), so
    # per-slab work stays O(slab) with O(1) scatter/gather passes.
    change = has & (v != pv)
    chg_i = change.astype(jnp.int64)
    cchg = jnp.cumsum(chg_i)
    slot = jnp.where(change, cchg, k + 1)
    pch = jnp.full(k + 2, -1, dtype=jnp.int64).at[slot].set(
        jnp.where(change, idx, -1))
    tch = jnp.zeros(k + 2).at[slot].set(jnp.where(change, t, 0.0))
    prev_ord = cchg - chg_i
    gstart = start_idx[seg]
    run_start = jnp.where(pch[prev_ord] >= gstart, tch[prev_ord],
                          run_t[seg])
    run_dur = jnp.where(change, t - run_start, 0.0)
    chg_before_slab = prev_ord - (cchg - chg_i)[start_idx][seg]
    run_rec = change & (n_changes[seg] + chg_before_slab >= 1)

    ord_last = cchg[end_idx]
    new_run_t = jnp.where(pch[ord_last] >= start_idx,
                          tch[ord_last], run_t)
    new_n_changes = n_changes + jax.ops.segment_sum(
        change.astype(jnp.int64), seg, num_segments=u)

    counts = jax.ops.segment_sum(jnp.ones(k, dtype=jnp.int64), seg,
                                 num_segments=u)
    sum_vc = jax.ops.segment_sum(vc, seg, num_segments=u)
    out = ((vc < env_lo[seg]) | (vc > env_hi[seg])).astype(jnp.int64)
    n_out = jax.ops.segment_sum(out, seg, num_segments=u)

    return (t[end_idx], v[end_idx], new_run_t, new_n_changes, counts,
            d_energy, d_energy_corr, d_win, d_win_corr, sum_vc, n_out,
            cum_e, cum_ec, vc, run_dur, run_rec)


def stream_ingest(t, v, seg, first, start_idx, end_idx, prev_t, prev_v,
                  has_prev, run_t, n_changes, gain, offset, tshift,
                  win_a, win_b, max_hold, env_lo, env_hi,
                  trapezoid: bool = False) -> Tuple:
    """Streaming-monitor ingest slab (see the numpy backend's reference
    docstring), fused into one jitted kernel; compiled once per
    (K, U) slab shape, so a fixed-tick replay reuses one compilation."""
    with enable_x64():
        outs = _stream_ingest_impl(
            jnp.asarray(t, jnp.float64), jnp.asarray(v, jnp.float64),
            jnp.asarray(seg, jnp.int64), jnp.asarray(first, jnp.bool_),
            jnp.asarray(start_idx, jnp.int64),
            jnp.asarray(end_idx, jnp.int64),
            jnp.asarray(prev_t, jnp.float64),
            jnp.asarray(prev_v, jnp.float64),
            jnp.asarray(has_prev, jnp.bool_),
            jnp.asarray(run_t, jnp.float64),
            jnp.asarray(n_changes, jnp.int64),
            jnp.asarray(gain, jnp.float64),
            jnp.asarray(offset, jnp.float64),
            jnp.asarray(tshift, jnp.float64),
            jnp.asarray(win_a, jnp.float64),
            jnp.asarray(win_b, jnp.float64),
            jnp.asarray(max_hold, jnp.float64),
            jnp.asarray(env_lo, jnp.float64),
            jnp.asarray(env_hi, jnp.float64),
            bool(trapezoid))
    return tuple(np.asarray(o) for o in outs)


@functools.partial(jax.jit, static_argnums=(15,))
def _stream_ingest_grid_impl(ts, v, prev_t, prev_v, has_prev, run_t,
                             n_changes, gain, offset, tshift, win_a,
                             win_b, max_hold, env_lo, env_hi,
                             trapezoid: bool):
    d, m = v.shape
    pt = jnp.concatenate(
        [prev_t[:, None],
         jnp.broadcast_to(ts[:-1][None, :], (d, m - 1))], axis=1)
    pv = jnp.concatenate([prev_v[:, None], v[:, :-1]], axis=1)
    has = jnp.concatenate(
        [has_prev[:, None], jnp.ones((d, m - 1), dtype=bool)], axis=1)

    g = gain[:, None]
    off = offset[:, None]
    vc = (v - off) / g
    pvc = (pv - off) / g
    dt = ts[None, :] - pt
    hold = jnp.minimum(dt, max_hold[:, None])
    dens_r = 0.5 * (pv + v) if trapezoid else pv
    dens_c = 0.5 * (pvc + vc) if trapezoid else pvc
    inc = jnp.where(has, dens_r * hold, 0.0)
    inc_c = jnp.where(has, dens_c * hold, 0.0)
    cum_e = jnp.cumsum(inc, axis=1)
    cum_ec = jnp.cumsum(inc_c, axis=1)

    a = win_a[:, None]
    b = win_b[:, None]
    w_inc = jnp.where(
        has & (pt >= a),
        dens_r * jnp.maximum(jnp.minimum(pt + hold, b) - pt, 0.0), 0.0)
    pts = pt - tshift[:, None]
    w_inc_c = jnp.where(
        has & (pts >= a),
        dens_c * jnp.maximum(jnp.minimum(pts + hold, b) - pts, 0.0), 0.0)

    # run tracking: every row shares the slab's single tick vector, so
    # the previous change column is a plain row-wise running maximum of
    # change positions (the numpy reference's ``maximum.accumulate``) —
    # gathers from the 1-D ``ts``, no scatters (XLA CPU scatters are
    # serial and dominated this kernel's profile)
    change = has & (v != pv)
    chg_i = change.astype(jnp.int64)
    cchg = jnp.cumsum(chg_i, axis=1)
    tsb = jnp.broadcast_to(ts[None, :], (d, m))
    cols = lax.broadcasted_iota(jnp.int64, (d, m), 1)
    ci = jnp.where(change, cols, jnp.int64(-1))
    acc = lax.cummax(ci, axis=1)                  # last change ≤ col j
    acc_excl = jnp.concatenate(
        [jnp.full((d, 1), -1, dtype=jnp.int64), acc[:, :-1]], axis=1)
    run_start = jnp.where(acc_excl >= 0, ts[jnp.maximum(acc_excl, 0)],
                          run_t[:, None])
    run_dur = jnp.where(change, tsb - run_start, 0.0)
    prev_ord = cchg - chg_i                       # changes strictly < j
    run_rec = change & (n_changes[:, None] + prev_ord >= 1)
    last = acc[:, -1]
    new_run_t = jnp.where(last >= 0, ts[jnp.maximum(last, 0)], run_t)
    new_n_changes = n_changes + cchg[:, -1]

    av = jnp.abs(vc)
    out = (vc < env_lo[:, None]) | (vc > env_hi[:, None])
    return (v[:, -1], new_run_t, new_n_changes,
            cum_e[:, -1], cum_ec[:, -1],
            jnp.sum(w_inc, axis=1), jnp.sum(w_inc_c, axis=1),
            jnp.sum(vc, axis=1), jnp.sum(vc * vc, axis=1),
            jnp.sum(av, axis=1), jnp.max(av, axis=1),
            jnp.sum(out, axis=1).astype(jnp.int64),
            cum_e, cum_ec, run_dur, run_rec)


def stream_ingest_grid(ts, v, prev_t, prev_v, has_prev, run_t, n_changes,
                       gain, offset, tshift, win_a, win_b, max_hold,
                       env_lo, env_hi, trapezoid: bool = False) -> Tuple:
    """Rectangular-slab streaming ingest (see the numpy backend's
    reference docstring) fused into one jitted kernel; compiled once per
    (D, M) slab shape, so a fixed-tick replay reuses one compilation."""
    ts = np.asarray(ts, dtype=np.float64)
    v = np.asarray(v, dtype=np.float64)
    d, m = v.shape
    if m == 0:      # empty slab: state passes through untouched
        z = np.zeros((d, 0))
        return (np.array(prev_v, dtype=np.float64),
                np.array(run_t, dtype=np.float64),
                np.array(n_changes, dtype=np.int64),
                np.zeros(d), np.zeros(d), np.zeros(d), np.zeros(d),
                np.zeros(d), np.zeros(d), np.zeros(d), np.zeros(d),
                np.zeros(d, dtype=np.int64), z, z, z,
                np.zeros((d, 0), dtype=bool))
    with enable_x64():
        outs = _stream_ingest_grid_impl(
            jnp.asarray(ts, jnp.float64), jnp.asarray(v, jnp.float64),
            jnp.asarray(prev_t, jnp.float64),
            jnp.asarray(prev_v, jnp.float64),
            jnp.asarray(has_prev, jnp.bool_),
            jnp.asarray(run_t, jnp.float64),
            jnp.asarray(n_changes, jnp.int64),
            jnp.asarray(gain, jnp.float64),
            jnp.asarray(offset, jnp.float64),
            jnp.asarray(tshift, jnp.float64),
            jnp.asarray(win_a, jnp.float64),
            jnp.asarray(win_b, jnp.float64),
            jnp.asarray(max_hold, jnp.float64),
            jnp.asarray(env_lo, jnp.float64),
            jnp.asarray(env_hi, jnp.float64),
            bool(trapezoid))
    return tuple(np.asarray(o) for o in outs)


@jax.jit
def _err_moments_impl(e):
    mean = jnp.mean(e)
    ae = jnp.abs(e)
    return mean, jnp.sum((e - mean) ** 2), jnp.mean(ae), jnp.max(ae)


def err_moments(e: np.ndarray):
    """One slab's ``(count, mean, M2, mean_abs, max_abs)`` reduction (see
    the numpy backend) as a fused jitted kernel."""
    e = np.asarray(e, dtype=np.float64)
    if e.size == 0:
        return 0, 0.0, 0.0, 0.0, 0.0
    with enable_x64():
        mean, m2, mean_abs, max_abs = _err_moments_impl(
            jnp.asarray(e, jnp.float64))
    return (int(e.size), float(mean), float(m2), float(mean_abs),
            float(max_abs))


@functools.partial(jax.jit, static_argnums=(10,))
def _snapshot_energy_at_impl(tq, last_t, dens, has, first_t, base,
                             max_hold, ring_t, ring_dens, ring_base,
                             with_ring: bool):
    tqc = tq[:, None]                                       # [Q, 1]
    dt = tqc - last_t[None, :]
    hold = jnp.minimum(dt, max_hold[None, :])
    live = has[None, :] & (dt >= 0.0)
    e_live = jnp.where(live, base[None, :] + dens[None, :] * hold, 0.0)
    covered = live | ~has[None, :] | (tqc <= first_t[None, :])
    started = has[None, :] & (tqc > first_t[None, :])
    e = jnp.where(started, e_live, 0.0)
    past = started & (tqc < last_t[None, :])
    if with_ring:
        j = jax.vmap(
            lambda row: jnp.searchsorted(row, tq, side="right"))(ring_t) - 1
        ok = j >= 0                                         # [N, Q]
        jc = jnp.clip(j, 0, ring_t.shape[1] - 1)
        rt = jnp.take_along_axis(ring_t, jc, axis=1)
        rd = jnp.take_along_axis(ring_dens, jc, axis=1)
        rb = jnp.take_along_axis(ring_base, jc, axis=1)
        hold_p = jnp.minimum(tqc - rt.T, max_hold[None, :])
        e_past = rb.T + rd.T * hold_p
        sel = past & ok.T
        e = jnp.where(sel, e_past, e)
        covered = covered | sel
    return jnp.where(covered, e, jnp.nan), covered


def snapshot_energy_at(tq: np.ndarray, last_t: np.ndarray,
                       dens: np.ndarray, has: np.ndarray,
                       first_t: np.ndarray, base: np.ndarray,
                       max_hold: np.ndarray, ring_t, ring_dens, ring_base):
    """Batched snapshot-view energy query (see the numpy backend's
    reference docstring) as one jitted [Q, N] kernel."""
    with_ring = ring_t is not None
    if not with_ring:
        r = np.zeros((last_t.shape[0], 0))
        ring_t = ring_dens = ring_base = r
    with enable_x64():
        e, covered = _snapshot_energy_at_impl(
            jnp.asarray(tq, jnp.float64), jnp.asarray(last_t, jnp.float64),
            jnp.asarray(dens, jnp.float64), jnp.asarray(has, jnp.bool_),
            jnp.asarray(first_t, jnp.float64), jnp.asarray(base, jnp.float64),
            jnp.asarray(max_hold, jnp.float64),
            jnp.asarray(ring_t, jnp.float64),
            jnp.asarray(ring_dens, jnp.float64),
            jnp.asarray(ring_base, jnp.float64), with_ring)
    return np.asarray(e), np.asarray(covered)
