"""JAX implementation of the fleet-engine kernels (`jax.jit` + `vmap`).

Same signatures, same semantics as
:mod:`repro.core.engine_backend.numpy_backend` — NumPy arrays in, NumPy
arrays out — with the array math dispatched through XLA:

* row-wise binary search is ``vmap(jnp.searchsorted)``;
* the logarithmic-filter recurrence ``y_{i+1} = a_i·y_i + b_i`` (affine
  per segment) runs as a ``lax.associative_scan`` over segments —
  O(log S) depth instead of the NumPy backend's sequential Python loop;
* the poll-counting closed form is one fused jitted kernel.

Everything is traced under ``jax.experimental.enable_x64`` so float64
semantics match NumPy bit-for-bit on elementwise arithmetic; only
reduction/scan association order differs, which is why the parity
contract is "within one reporting quantum", not bitwise
(``tests/test_engine_backend.py`` pins it).  Compiled kernels are cached
by shape, so repeated trials of a fixed fleet re-use one compilation.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import enable_x64

from repro.core.engine_backend.pytrees import (PollGrid, ReadingSchedule,
                                               TimelineArrays)

name = "jax"

_FAR = np.iinfo(np.int64).max // 2


def _searchsorted_rows(a, v, side: str):
    g = v.shape[0]
    if a.shape[0] == 1 and g > 1:
        a = jnp.broadcast_to(a, (g, a.shape[1]))
    return jax.vmap(
        lambda ar, vr: jnp.searchsorted(ar, vr, side=side))(a, v)


def _broadcast_rows(tl: TimelineArrays, g: int) -> TimelineArrays:
    r = tl.edges.shape[0]
    if r == g:
        return tl
    if r != 1:
        raise ValueError(f"{g} query rows for {r} timeline rows")
    return TimelineArrays(
        jnp.broadcast_to(tl.edges, (g, tl.edges.shape[1])),
        jnp.broadcast_to(tl.powers, (g, tl.powers.shape[1])),
        jnp.broadcast_to(tl.idle_w, (g,)),
        jnp.broadcast_to(tl.n_segs, (g,)))


@jax.jit
def _integral_impl(tl: TimelineArrays, t0, t1):
    g = t0.shape[0]
    seg = tl.powers * jnp.diff(tl.edges, axis=1)
    cum = jnp.concatenate(
        [jnp.zeros((tl.edges.shape[0], 1)), jnp.cumsum(seg, axis=1)],
        axis=1)
    tl = _broadcast_rows(tl, g)
    cum = jnp.broadcast_to(cum, (g, cum.shape[1]))
    e, p, idle, ns = tl
    first = e[:, 0][:, None]
    last = e[:, -1][:, None]
    hi_idx = jnp.maximum(ns - 1, 0)[:, None]

    def eval_I(t):
        tc = jnp.clip(t, first, last)
        idx = jnp.clip(_searchsorted_rows(e, tc, "right") - 1, 0, hi_idx)
        inner = (jnp.take_along_axis(cum, idx, axis=1)
                 + jnp.take_along_axis(p, idx, axis=1)
                 * (tc - jnp.take_along_axis(e, idx, axis=1)))
        before = jnp.minimum(t - first, 0.0) * idle[:, None]
        after = jnp.maximum(t - last, 0.0) * idle[:, None]
        return inner + before + after

    return eval_I(t1) - eval_I(t0)


@jax.jit
def _boxcar_impl(tl: TimelineArrays, t0, t1):
    dt = jnp.maximum(t1 - t0, 1e-12)
    return _integral_impl(tl, t0, t1) / dt


@jax.jit
def _estimation_impl(tl: TimelineArrays, t0, t1, model_gain):
    return _boxcar_impl(tl, t0, t1) * model_gain[:, None]


@jax.jit
def _log_filter_impl(tl: TimelineArrays, ticks, tau, t_lo, t_hi):
    g = ticks.shape[0]
    r = tl.edges.shape[0]
    ext_e = jnp.concatenate([jnp.full((r, 1), t_lo), tl.edges,
                             jnp.full((r, 1), t_hi)], axis=1)
    ext_p = jnp.concatenate([tl.idle_w[:, None], tl.powers,
                             tl.idle_w[:, None]], axis=1)
    n_seg = ext_p.shape[1]
    dts = jnp.broadcast_to(jnp.diff(ext_e, axis=1), (g, n_seg))
    sp = jnp.broadcast_to(ext_p, (g, n_seg))
    # each segment advances the filter state affinely:
    #   y_{i+1} = a_i · y_i + b_i  with  a_i = e^{-dt_i/tau},
    #   b_i = P_i (1 - a_i); zero-width padding steps are the identity map
    decay = jnp.exp(-dts / tau[:, None])
    a_seg = jnp.where(dts > 0, decay, 1.0)
    b_seg = jnp.where(dts > 0, sp * (1.0 - decay), 0.0)

    def compose(lo, hi):
        a1, b1 = lo
        a2, b2 = hi
        return (a1 * a2, b1 * a2 + b2)

    A, B = lax.associative_scan(compose, (a_seg, b_seg), axis=1)
    y0 = jnp.broadcast_to(tl.idle_w, (g,))[:, None]
    y = jnp.concatenate([y0, A * y0 + B], axis=1)          # [g, n_seg+1]

    ext_e_g = jnp.broadcast_to(ext_e, (g, n_seg + 1))
    idx = jnp.clip(_searchsorted_rows(ext_e, ticks, "right") - 1,
                   0, n_seg - 1)
    y_at = jnp.take_along_axis(y, idx, axis=1)
    sp_at = jnp.take_along_axis(sp, idx, axis=1)
    e_at = jnp.take_along_axis(ext_e_g, idx, axis=1)
    return sp_at + (y_at - sp_at) * jnp.exp(-(ticks - e_at) / tau[:, None])


@jax.jit
def _query_slots_impl(sched: ReadingSchedule, tq):
    T = sched.update_period_s[:, None]
    phase = sched.phase[:, None]
    m = sched.ticks.shape[1]
    j = jnp.floor((tq - phase) / T).astype(jnp.int64) - sched.k0[:, None]
    j = jnp.clip(j, 0, m - 1)
    for _ in range(2):
        tj = jnp.take_along_axis(sched.ticks, j, axis=1)
        j = jnp.where((tj > tq) & (j > 0), j - 1, j)
    for _ in range(2):
        jn = jnp.minimum(j + 1, m - 1)
        tn = jnp.take_along_axis(sched.ticks, jn, axis=1)
        j = jnp.where((tn <= tq) & (jn > j), jn, j)
    return jnp.clip(j, sched.first[:, None], sched.last[:, None])


@jax.jit
def _poll_counts_impl(sched: ReadingSchedule, t0, t1, period_s,
                      grid_offset, a, b):
    n = a.shape[0]
    m_i = jnp.floor((t1 - t0) / period_s).astype(jnp.int64)

    def q(idx):
        return t0 + period_s * idx

    def r(idx):
        return (t0 + period_s * idx) + grid_offset

    j0 = jnp.ceil((a - grid_offset - t0) / period_s).astype(jnp.int64)
    j1 = jnp.floor((b - grid_offset - t0) / period_s).astype(jnp.int64)
    for _ in range(2):
        j0 = jnp.where(r(j0 - 1) >= a, j0 - 1, j0)
        j0 = jnp.where(r(j0) < a, j0 + 1, j0)
        j1 = jnp.where(r(j1 + 1) <= b, j1 + 1, j1)
        j1 = jnp.where(r(j1) > b, j1 - 1, j1)
    j0 = jnp.maximum(j0, 0)
    j1 = jnp.minimum(j1, m_i - 1)

    ticks = sched.ticks
    m = ticks.shape[1]
    slot = jnp.arange(m)[None, :]
    lo = jnp.ceil((ticks - t0) / period_s).astype(jnp.int64)
    for _ in range(2):
        lo = jnp.where(q(lo - 1) >= ticks, lo - 1, lo)
        lo = jnp.where(q(lo) < ticks, lo + 1, lo)
    hi = jnp.concatenate([lo[:, 1:] - 1, jnp.full((n, 1), _FAR)], axis=1)
    lo = jnp.where(slot == sched.first[:, None], jnp.int64(0), lo)
    hi = jnp.where(slot == sched.last[:, None], _FAR, hi)
    counts = (jnp.minimum(hi, (j1 - 1)[:, None])
              - jnp.maximum(lo, j0[:, None]) + 1)
    valid = ((slot >= sched.first[:, None])
             & (slot <= sched.last[:, None]))
    counts = jnp.where(valid, jnp.maximum(counts, 0), 0)

    slot_b = _query_slots_impl(sched, q(j1.astype(jnp.float64))[:, None])
    tail_dt = b - r(j1.astype(jnp.float64))
    return counts, slot_b[:, 0], tail_dt, j1 >= j0


# -- public wrappers: NumPy in, NumPy out -----------------------------------

def boxcar_means(tl: TimelineArrays, t0: np.ndarray,
                 t1: np.ndarray) -> np.ndarray:
    with enable_x64():
        return np.asarray(_boxcar_impl(tl, jnp.asarray(t0, jnp.float64),
                                       jnp.asarray(t1, jnp.float64)))


def estimation_means(tl: TimelineArrays, t0: np.ndarray, t1: np.ndarray,
                     model_gain: np.ndarray) -> np.ndarray:
    with enable_x64():
        return np.asarray(_estimation_impl(
            tl, jnp.asarray(t0, jnp.float64), jnp.asarray(t1, jnp.float64),
            jnp.asarray(model_gain, jnp.float64)))


def timeline_integral(tl: TimelineArrays, t0: np.ndarray,
                      t1: np.ndarray) -> np.ndarray:
    with enable_x64():
        return np.asarray(_integral_impl(tl, jnp.asarray(t0, jnp.float64),
                                         jnp.asarray(t1, jnp.float64)))


def log_filter(tl: TimelineArrays, ticks: np.ndarray,
               tau: np.ndarray) -> np.ndarray:
    tau = np.asarray(tau, dtype=np.float64)
    # concrete pad bounds (cheap NumPy reductions) keep the jitted kernel
    # free of host round-trips; they only need to cover idle
    t_lo = (min(float(np.min(ticks)), float(np.min(tl.t_start)))
            - 5.0 * float(np.max(tau)))
    t_hi = max(float(np.max(ticks)), float(np.max(tl.t_end))) + 1e-9
    with enable_x64():
        return np.asarray(_log_filter_impl(
            tl, jnp.asarray(ticks, jnp.float64), jnp.asarray(tau),
            jnp.float64(t_lo), jnp.float64(t_hi)))


def poll_counts(sched: ReadingSchedule, grid: PollGrid, a: np.ndarray,
                b: np.ndarray) -> Tuple[np.ndarray, np.ndarray,
                                        np.ndarray, np.ndarray]:
    with enable_x64():
        counts, slot_b, tail_dt, nonempty = _poll_counts_impl(
            sched, jnp.float64(grid.t0),
            jnp.asarray(grid.t1, jnp.float64),
            jnp.float64(grid.period_s), jnp.float64(grid.grid_offset),
            jnp.asarray(a, jnp.float64), jnp.asarray(b, jnp.float64))
    return (np.asarray(counts), np.asarray(slot_b),
            np.asarray(tail_dt), np.asarray(nonempty))


def query_slots(sched: ReadingSchedule, tq: np.ndarray) -> np.ndarray:
    with enable_x64():
        return np.asarray(_query_slots_impl(
            sched, jnp.asarray(tq, jnp.float64)))


@jax.jit
def _err_moments_impl(e):
    mean = jnp.mean(e)
    ae = jnp.abs(e)
    return mean, jnp.sum((e - mean) ** 2), jnp.mean(ae), jnp.max(ae)


def err_moments(e: np.ndarray):
    """One slab's ``(count, mean, M2, mean_abs, max_abs)`` reduction (see
    the numpy backend) as a fused jitted kernel."""
    e = np.asarray(e, dtype=np.float64)
    if e.size == 0:
        return 0, 0.0, 0.0, 0.0, 0.0
    with enable_x64():
        mean, m2, mean_abs, max_abs = _err_moments_impl(
            jnp.asarray(e, jnp.float64))
    return (int(e.size), float(mean), float(m2), float(mean_abs),
            float(max_abs))
