"""Multi-backend execution layer for the fleet engine.

The batched sensor simulation reduces to four pure array kernels — the
three transient responses (trailing boxcar, first-order "logarithmic"
filter, estimation proxy) and the closed-form poll counting behind
``SensorBank.integrate_polled``.  This package holds one implementation
per array backend:

* :mod:`~repro.core.engine_backend.numpy_backend` — the reference
  semantics; always available.
* :mod:`~repro.core.engine_backend.jax_backend` — ``jax.jit`` + ``vmap``
  kernels (``lax.associative_scan`` for the filter recurrence), traced
  under x64 so results stay within one reporting quantum of NumPy.
* :mod:`~repro.core.engine_backend.pallas_backend` — fused Pallas
  kernels for the streaming hot loops (``stream_ingest``,
  ``stream_ingest_grid``, ``step_integrate``, ``log_filter``), with
  ``interpret=True`` fallback on CPU-only hosts; gather-bound kernels
  delegate to the jax tier.

Backends are plain modules sharing one function signature set over the
pytree containers in :mod:`~repro.core.engine_backend.pytrees`
(``TimelineArrays``, ``ReadingSchedule``, ``PollGrid``).  Select one with
``SensorBank(..., backend="jax")`` / ``fleet_audit(..., backend="auto")``
or grab it directly via :func:`get_backend`.  See ``docs/backends.md``.

The package also hosts :mod:`~repro.core.engine_backend.vecrng` — N
lock-step per-seed RNG streams, bitwise-compatible with
``np.random.default_rng`` — the substrate of the array-native workload
synthesis and the engine's vectorized noise/jitter draws
(``docs/scaling.md``).
"""
from __future__ import annotations

import importlib
import importlib.util
from typing import Optional, Tuple

from repro.core.engine_backend import numpy_backend
from repro.core.engine_backend.pytrees import (PollGrid, ReadingSchedule,
                                               TimelineArrays)

__all__ = ["available_backends", "get_backend", "has_jax",
           "resolve_backend", "PollGrid", "ReadingSchedule",
           "TimelineArrays", "numpy_backend"]

_BACKENDS = {"numpy": numpy_backend}
_KNOWN = ("numpy", "jax", "pallas")


_HAS_JAX: Optional[bool] = None


def has_jax() -> bool:
    """Whether the jax backend can actually be loaded.

    A present-but-broken install (jax without a matching jaxlib) must
    read as unavailable so ``backend="auto"`` degrades to numpy instead
    of crashing; that means probing with a real import, not just
    ``find_spec``.  The result is cached — the probe runs once."""
    global _HAS_JAX
    if _HAS_JAX is None:
        if "jax" in _BACKENDS:
            _HAS_JAX = True
        elif importlib.util.find_spec("jax") is None:
            _HAS_JAX = False
        else:
            try:
                importlib.import_module("jax")
                _HAS_JAX = True
            except Exception:
                _HAS_JAX = False
    return _HAS_JAX


def available_backends() -> Tuple[str, ...]:
    """Names accepted by :func:`get_backend`, in preference order.

    The pallas tier rides on the same jax install (its kernels fall back
    to ``interpret=True`` without an accelerator), so both accelerated
    tiers appear whenever jax imports."""
    return ("numpy", "jax", "pallas") if has_jax() else ("numpy",)


# the minimum kernel surface a backend *object* must expose to stand in
# for a named backend module (the audit path's working set)
_KERNEL_SURFACE = ("boxcar_means", "estimation_means", "log_filter",
                   "query_slots", "poll_counts", "err_moments")


def resolve_backend(name):
    """Normalise a backend selector: ``None`` → ``"numpy"`` (the default
    and reference), ``"auto"`` → ``"jax"`` when importable else
    ``"numpy"``.  Asking for ``"jax"`` without jax installed raises.

    A non-string *backend object* (module-like: anything exposing the
    kernel signature set, e.g. a
    :class:`~repro.core.fleet_engine_shard.ShardedBackend`) passes
    through unchanged — that is how composed tiers plug into
    ``SensorBank``/``fleet_audit`` without registering a global name."""
    if name is None:
        return "numpy"
    if not isinstance(name, str):
        missing = [k for k in _KERNEL_SURFACE if not hasattr(name, k)]
        if missing:
            raise ValueError(
                f"backend object {name!r} lacks kernel(s) "
                f"{', '.join(missing)}; a backend must expose "
                f"{', '.join(_KERNEL_SURFACE)}")
        return name
    if name == "auto":
        return "jax" if has_jax() else "numpy"
    if name not in _KNOWN:
        raise ValueError(
            f"unknown backend '{name}'; known: {', '.join(_KNOWN)}")
    if name in ("jax", "pallas") and not has_jax():
        raise ValueError(f"backend '{name}' requested but jax is not "
                         "installed; use backend='numpy' or 'auto'")
    return name


def get_backend(name=None):
    """The backend module (or passed-through backend object) for ``name``
    (see :func:`resolve_backend`)."""
    name = resolve_backend(name)
    if not isinstance(name, str):
        return name
    if name not in _BACKENDS:
        _BACKENDS[name] = importlib.import_module(
            f"repro.core.engine_backend.{name}_backend")
    return _BACKENDS[name]
