"""Vectorized per-seed RNG streams, bitwise-compatible with numpy.

Every per-device randomness contract in this repo is expressed as "device
``i`` draws from ``np.random.default_rng(seed_i)``" — hidden sensor
parameters, reading noise, poll jitter, scenario workload shapes.  The
scalar form is exact but unbatchable: constructing N ``Generator`` objects
costs ~15 µs each, which at 100k devices is more wall-time than the whole
audit they feed (``BENCH_fleet.json`` measured 11.2 s of workload
synthesis against 7.9 s of audit).

:class:`VecStreams` removes the object-per-device cost without touching
the numbers: it advances N *independent* PCG64 states in lock-step as
``[N]`` uint64 arrays, replaying numpy's own algorithms bit-for-bit —

* the ``SeedSequence`` entropy-mixing hash (O'Neill's ``seed_seq_fe``,
  32-bit arithmetic, vectorized here over seeds);
* the PCG64 XSL-RR generator (128-bit LCG as hi/lo uint64 pairs with an
  explicit 64×64→128 multiply);
* ``next_double`` / ``uniform`` (fixed one-word consumption);
* the ziggurat ``standard_normal`` / ``standard_exponential`` samplers
  (variable consumption: rejected lanes retry on their *own* streams
  while settled lanes stop consuming — acceptance tables in
  :mod:`._ziggurat` are bit-exact extractions of numpy's compiled
  constants, see ``tools/gen_vecrng_tables.py``);
* ``poisson`` (count-by-uniform-products below λ=10, the PTRS transformed
  rejection above, including numpy's ``loggam`` Stirling evaluation).

Equivalence contract: ``VecStreams(seeds).method(...)`` equals
``np.random.default_rng(seeds[i]).method(...)`` lane-for-lane, bitwise,
for every method above (pinned by ``tests/test_vecrng.py``).  Two known
ulp-level caveats are handled explicitly:

* the ziggurat *tail* paths call libm's ``log1p`` through ``math`` on the
  (rare, ~3·10⁻⁴) tail lanes — numpy's vectorized ``np.log1p`` ufunc
  differs from the C scalar ``npy_log1p`` by 1 ulp on ~7 % of inputs,
  which would desynchronize the stream;
* acceptance thresholds derived rather than extracted (``ki``/``fe``…)
  could in principle sit one ulp off numpy's, which only matters for a
  draw landing exactly on the boundary ulp (~2⁻⁵² per draw).

The wedge/PTRS accept decisions use ``np.exp``/``np.log``; a 1-ulp ufunc
vs libm difference there flips a comparison only when the two sides agree
to ~10⁻¹⁶ relative — none observed across the 10⁷-draw parity sweep.

Like the rest of :mod:`repro.core.engine_backend`, this module depends
only on numpy and sits at the bottom of the dependency graph.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple, Union

import numpy as np

from repro.core.engine_backend._ziggurat import (EXP_FE, EXP_KE, EXP_WE,
                                                 NORMAL_FI, NORMAL_KI,
                                                 NORMAL_WI)

_U32 = np.uint32
_U64 = np.uint64

# -- SeedSequence constants (numpy/random/bit_generator.pyx) ----------------
_INIT_A = _U32(0x43b0d7e5)
_MULT_A = _U32(0x931e8875)
_INIT_B = _U32(0x8b51f9dd)
_MULT_B = _U32(0x58f38ded)
_MIX_MULT_L = _U32(0xca01f9dd)
_MIX_MULT_R = _U32(0x4973f715)
_XSHIFT = _U32(16)
_POOL_SIZE = 4

# -- PCG64 (XSL-RR 128/64) constants ----------------------------------------
_PCG_MULT_HI = _U64(0x2360ed051fc65da4)
_PCG_MULT_LO = _U64(0x4385df649fccf645)

_MASK32 = _U64(0xffffffff)
_INV53 = 1.0 / 9007199254740992.0            # 2**-53

# -- ziggurat scalar constants (numpy's literals) ---------------------------
NOR_R = 3.6541528853610088                   # ziggurat_nor_r
NOR_INV_R = 0.2736612373297583               # ziggurat_nor_inv_r == fl(1/R)
#   (solved against libm log1p over 502 observed tail draws — exact on all)
EXP_R = 7.697117470131050                    # ziggurat_exp_r

_LOGGAM_A = (8.333333333333333e-02, -2.777777777777778e-03,
             7.936507936507937e-04, -5.952380952380952e-04,
             8.417508417508418e-04, -1.917526917526918e-03,
             6.410256410256410e-03, -2.955065359477124e-02,
             1.796443723688307e-01, -1.392432216905900e+00)
_LOG_2PI = 1.8378770664093453e+00


def seedseq_state(seeds: np.ndarray, n_words_64: int) -> np.ndarray:
    """Vectorized ``np.random.SeedSequence(seed).generate_state(n, uint64)``
    for an ``[N]`` array of integer seeds below 2**64; returns ``[N, n]``.

    Bitwise identical per row (the entropy of an int below 2**32 is one
    32-bit word; the pool fill pads with zeros, so always hashing a
    high word — zero where absent — reproduces numpy's variable-length
    coercion exactly).
    """
    seeds = np.asarray(seeds, dtype=np.uint64)
    n = seeds.shape[0]
    lo = (seeds & _MASK32).astype(_U32)
    hi = (seeds >> _U64(32)).astype(_U32)
    with np.errstate(over="ignore"):
        hash_const = np.full(n, _INIT_A, dtype=_U32)

        def hashmix(value):
            nonlocal hash_const
            value = value ^ hash_const
            hash_const = hash_const * _MULT_A
            value = value * hash_const
            value ^= value >> _XSHIFT
            return value

        def mix(x, y):
            r = (x * _MIX_MULT_L) - (y * _MIX_MULT_R)
            r ^= r >> _XSHIFT
            return r

        pool = np.zeros((n, _POOL_SIZE), dtype=_U32)
        pool[:, 0] = hashmix(lo)
        pool[:, 1] = hashmix(hi)
        pool[:, 2] = hashmix(np.zeros(n, dtype=_U32))
        pool[:, 3] = hashmix(np.zeros(n, dtype=_U32))
        for i_src in range(_POOL_SIZE):
            for i_dst in range(_POOL_SIZE):
                if i_src != i_dst:
                    pool[:, i_dst] = mix(pool[:, i_dst],
                                         hashmix(pool[:, i_src]))

        hash_const = np.full(n, _INIT_B, dtype=_U32)
        out32 = np.empty((n, n_words_64 * 2), dtype=_U32)
        for i_dst in range(n_words_64 * 2):
            v = pool[:, i_dst % _POOL_SIZE].copy()
            v ^= hash_const
            hash_const = hash_const * _MULT_B
            v = v * hash_const
            v ^= v >> _XSHIFT
            out32[:, i_dst] = v
    o = out32.astype(_U64).reshape(n, n_words_64, 2)
    return o[:, :, 0] | (o[:, :, 1] << _U64(32))


def _mul128(ahi, alo, bhi, blo):
    """(hi, lo) of ``a * b mod 2**128`` for uint64 hi/lo pairs."""
    with np.errstate(over="ignore"):
        a0 = alo & _MASK32
        a1 = alo >> _U64(32)
        b0 = blo & _MASK32
        b1 = blo >> _U64(32)
        p00 = a0 * b0
        p01 = a0 * b1
        p10 = a1 * b0
        mid = (p00 >> _U64(32)) + (p01 & _MASK32) + (p10 & _MASK32)
        lo = (p00 & _MASK32) | (mid << _U64(32))
        hi = (a1 * b1 + (p01 >> _U64(32)) + (p10 >> _U64(32))
              + (mid >> _U64(32)))
        hi = hi + alo * bhi + ahi * blo
    return hi, lo


def _add128(ahi, alo, bhi, blo):
    with np.errstate(over="ignore"):
        lo = alo + blo
        hi = ahi + bhi + (lo < alo).astype(_U64)
    return hi, lo


def _output(state_hi, state_lo):
    """PCG64 XSL-RR output function."""
    with np.errstate(over="ignore"):
        rot = state_hi >> _U64(58)
        x = state_hi ^ state_lo
        return (x >> rot) | (x << ((_U64(64) - rot) & _U64(63)))


class VecStreams:
    """``[N]`` independent ``default_rng(seed_i)``-equivalent streams.

    Every draw method advances each lane exactly as the scalar generator
    would — including variable ziggurat/poisson consumption per lane —
    so interleaving draw kinds keeps lane ``i`` bitwise on
    ``default_rng(seeds[i])``'s trajectory.  ``mask`` arguments restrict
    a draw to a subset of lanes; masked-off lanes neither consume nor
    produce (their output slot is 0).
    """

    def __init__(self, seeds: np.ndarray):
        st = seedseq_state(seeds, 4)
        n = st.shape[0]
        with np.errstate(over="ignore"):
            self._inc_hi = (st[:, 2] << _U64(1)) | (st[:, 3] >> _U64(63))
            self._inc_lo = (st[:, 3] << _U64(1)) | _U64(1)
        self._hi = np.zeros(n, dtype=_U64)
        self._lo = np.zeros(n, dtype=_U64)
        self._step()
        self._hi, self._lo = _add128(self._hi, self._lo, st[:, 0], st[:, 1])
        self._step()

    @property
    def n_lanes(self) -> int:
        return self._hi.shape[0]

    # -- raw stream -------------------------------------------------------
    def _step(self, mask: Optional[np.ndarray] = None) -> None:
        hi, lo = _mul128(self._hi, self._lo, _PCG_MULT_HI, _PCG_MULT_LO)
        hi, lo = _add128(hi, lo, self._inc_hi, self._inc_lo)
        if mask is None:
            self._hi, self._lo = hi, lo
        else:
            self._hi = np.where(mask, hi, self._hi)
            self._lo = np.where(mask, lo, self._lo)

    def _next_raw(self, mask: Optional[np.ndarray] = None) -> np.ndarray:
        self._step(mask)
        return _output(self._hi, self._lo)

    def _next_double(self, mask: Optional[np.ndarray] = None) -> np.ndarray:
        return ((self._next_raw(mask) >> _U64(11)).astype(np.float64)
                * _INV53)

    # -- lane subsetting (used to retry rejected lanes compactly) ---------
    def _gather(self, idx: np.ndarray) -> "VecStreams":
        sub = object.__new__(VecStreams)
        sub._hi = self._hi[idx]
        sub._lo = self._lo[idx]
        sub._inc_hi = self._inc_hi[idx]
        sub._inc_lo = self._inc_lo[idx]
        return sub

    def _scatter(self, idx: np.ndarray, sub: "VecStreams") -> None:
        self._hi[idx] = sub._hi
        self._lo[idx] = sub._lo

    # -- deterministic shard substreams -----------------------------------
    def split(self, n_shards: int) -> list:
        """Partition the lanes into ``n_shards`` contiguous independent
        sub-banks (shard ``k`` owns lanes ``offsets[k]:offsets[k+1]``,
        ``np.array_split`` bounds).

        Each sub-bank carries *copies* of its lanes' states, so shards
        may draw concurrently from different threads/processes; because
        every lane is its own ``default_rng(seed_i)``-equivalent stream,
        drawing shard outputs and concatenating them in shard order is
        bitwise what the undivided bank produces.  This is the substrate
        of sharded workload synthesis (``docs/scaling.md``): shard
        ``k+1`` can synthesise while shard ``k`` audits without touching
        shared RNG state.
        """
        n_shards = int(n_shards)
        if not 1 <= n_shards <= self.n_lanes:
            raise ValueError(f"n_shards must be in [1, {self.n_lanes}], "
                             f"got {n_shards}")
        return [self._gather(idx) for idx in
                np.array_split(np.arange(self.n_lanes), n_shards)]

    def jumped(self, counts) -> "VecStreams":
        """A copy with lane ``i`` advanced ``counts[i]`` raw words
        (scalar ``counts`` broadcasts); ``self`` is untouched.

        The jump is the exact binary-lifting state transform
        (:meth:`_advance`), not replayed draws — O(log counts) 128-bit
        affine steps per lane — so a shard can start mid-stream at a
        known draw offset deterministically.
        """
        sub = self._gather(np.arange(self.n_lanes))
        counts = np.broadcast_to(np.asarray(counts, dtype=np.int64),
                                 (self.n_lanes,))
        if np.any(counts < 0):
            raise ValueError("jump counts must be >= 0")
        sub._advance(counts.copy())
        return sub

    # -- fixed-consumption draws ------------------------------------------
    def random(self, mask: Optional[np.ndarray] = None) -> np.ndarray:
        """One ``Generator.random()`` double per lane."""
        return self._next_double(mask)

    def uniform(self, low, high,
                mask: Optional[np.ndarray] = None) -> np.ndarray:
        """One ``Generator.uniform(low, high)`` per lane; ``low``/``high``
        may be scalars or ``[N]`` arrays (per-lane bounds)."""
        low = np.asarray(low, dtype=np.float64)
        high = np.asarray(high, dtype=np.float64)
        return low + (high - low) * self._next_double(mask)

    def _bit_transforms(self, n_bits: int):
        """Affine maps ``state -> A^(2^b)·state + c_b`` for b = 0..n_bits-1
        (binary lifting, exact mod 2**128).  ``A`` is lane-independent
        ([1] arrays); ``c`` carries the per-lane increment ([N])."""
        n = self.n_lanes
        bits = []
        ah, al = np.full(1, _PCG_MULT_HI), np.full(1, _PCG_MULT_LO)
        ch, cl = self._inc_hi.copy(), self._inc_lo.copy()
        for _ in range(n_bits):
            bits.append(((ah, al), (ch, cl)))
            nh, nl = _mul128(np.broadcast_to(ah, (n,)),
                             np.broadcast_to(al, (n,)), ch, cl)
            ch, cl = _add128(nh, nl, ch, cl)      # A·c + c
            ah, al = _mul128(ah, al, ah, al)      # A²
        return bits

    def _advance(self, counts: np.ndarray) -> None:
        """Jump lane ``i`` forward by ``counts[i]`` steps (exact)."""
        counts = np.asarray(counts, dtype=np.int64)
        if not np.any(counts):
            return
        n = self.n_lanes
        for b, ((pah, pal), (pch, pcl)) in enumerate(
                self._bit_transforms(int(counts.max()).bit_length())):
            sel = ((counts >> b) & 1).astype(bool)
            if not np.any(sel):
                continue
            hi, lo = _mul128(np.broadcast_to(pah, (n,)),
                             np.broadcast_to(pal, (n,)), self._hi, self._lo)
            hi, lo = _add128(hi, lo, pch, pcl)
            self._hi = np.where(sel, hi, self._hi)
            self._lo = np.where(sel, lo, self._lo)

    def raw_block(self, m: int) -> np.ndarray:
        """``[N, m]`` raw words *without* advancing lane states; column
        ``j`` is each lane's ``j``-th upcoming word.  Runs in ~2·√(m)
        lock-step rounds: boundary states every ``stride`` columns are
        built by repeated stride-step jumps, then ``stride`` single
        steps advance all boundaries in parallel.  Pure — commit
        consumption afterwards with :meth:`_advance`.
        """
        n = self.n_lanes
        stride = max(8, min(256, 1 << (max(int(m - 1).bit_length(), 2) // 2)))
        k = (m + stride - 1) // stride
        (ah, al), (ch, cl) = self._bit_transforms(
            stride.bit_length())[stride.bit_length() - 1]
        bh = np.empty((n, k), dtype=_U64)
        bl = np.empty((n, k), dtype=_U64)
        bh[:, 0], bl[:, 0] = self._hi, self._lo
        for q in range(1, k):
            hi, lo = _mul128(np.broadcast_to(ah, (n,)),
                             np.broadcast_to(al, (n,)),
                             bh[:, q - 1], bl[:, q - 1])
            bh[:, q], bl[:, q] = _add128(hi, lo, ch, cl)
        raws = np.empty((stride, n, k), dtype=_U64)
        inc_h = self._inc_hi[:, None]
        inc_l = self._inc_lo[:, None]
        for j in range(stride):
            hi, lo = _mul128(bh, bl, _PCG_MULT_HI, _PCG_MULT_LO)
            bh, bl = _add128(hi, lo, inc_h, inc_l)
            raws[j] = _output(bh, bl)
        return raws.transpose(1, 2, 0).reshape(n, k * stride)[:, :m]

    def uniform_block(self, low, high, counts) -> np.ndarray:
        """``[N, M]`` padded uniforms: lane ``i`` consumes ``counts[i]``
        draws — elementwise equal to
        ``default_rng(seed_i).uniform(low_i, high_i, size=counts[i])``.

        Uniform draws consume exactly one word each, so the whole block
        comes from :meth:`raw_block` (~2·√M lock-step rounds instead of
        an M-round Python loop); lane states end exactly ``counts[i]``
        steps ahead.  Peak memory is O(N·M); chunk at the call site for
        very long blocks.
        """
        counts = np.asarray(counts, dtype=np.int64)
        m = int(counts.max()) if counts.size else 0
        n = self.n_lanes
        if m == 0:
            return np.zeros((n, 0))
        low = np.asarray(low, dtype=np.float64)
        high = np.asarray(high, dtype=np.float64)
        if low.ndim == 1:
            low = low[:, None]
        if high.ndim == 1:
            high = high[:, None]
        raws = self.raw_block(m)
        u = (raws >> _U64(11)).astype(np.float64) * _INV53
        out = low + (high - low) * u
        cols = np.arange(m)[None, :]
        out[cols >= counts[:, None]] = 0.0
        self._advance(counts)        # commit exactly counts[i] words/lane
        return out

    # -- ziggurat samplers ------------------------------------------------
    def _standard_normal_once(self) -> Tuple[np.ndarray, np.ndarray]:
        """One ziggurat attempt on every lane; returns (value, settled)."""
        rr = self._next_raw()
        idx = (rr & _U64(0xff)).astype(np.int64)
        rs = rr >> _U64(8)
        sign = (rs & _U64(1)).astype(bool)
        rabs = (rs >> _U64(1)) & _U64(0x000fffffffffffff)
        x = rabs.astype(np.float64) * NORMAL_WI[idx]
        x = np.where(sign, -x, x)
        accept = rabs < NORMAL_KI[idx]
        out = np.where(accept, x, 0.0)
        done = accept.copy()

        tail = ~accept & (idx == 0)
        if np.any(tail):
            t_idx = np.flatnonzero(tail)
            sub = self._gather(t_idx)
            t_rabs = rabs[t_idx]
            val = np.empty(len(t_idx))
            need = np.ones(len(t_idx), dtype=bool)
            while np.any(need):
                u1 = sub._next_double(need)
                u2 = sub._next_double(need)
                # libm log1p: np.log1p strays 1 ulp on ~7 % of inputs
                l1 = np.array([math.log1p(-v) for v in u1])
                l2 = np.array([math.log1p(-v) for v in u2])
                xx = -NOR_INV_R * l1
                yy = -l2
                ok = need & (yy + yy > xx * xx)
                v = np.where((t_rabs >> _U64(8)) & _U64(1) != 0,
                             -(NOR_R + xx), NOR_R + xx)
                val = np.where(ok, v, val)
                need &= ~ok
            self._scatter(t_idx, sub)
            out[t_idx] = val
            done[t_idx] = True

        wedge = ~accept & (idx > 0)
        if np.any(wedge):
            w_idx = np.flatnonzero(wedge)
            sub = self._gather(w_idx)
            u = sub._next_double()
            self._scatter(w_idx, sub)
            xi = idx[w_idx]
            xw = x[w_idx]
            ok = ((NORMAL_FI[xi - 1] - NORMAL_FI[xi]) * u + NORMAL_FI[xi]
                  < np.exp(-0.5 * xw * xw))
            out[w_idx] = np.where(ok, xw, 0.0)
            done[w_idx] = ok
        return out, done

    def standard_normal(self, mask: Optional[np.ndarray] = None
                        ) -> np.ndarray:
        """One ``Generator.standard_normal()`` per lane."""
        n = self.n_lanes
        out = np.zeros(n)
        active = np.arange(n) if mask is None else np.flatnonzero(mask)
        sub = self._gather(active) if len(active) != n else self
        while True:
            vals, done = sub._standard_normal_once()
            out[active[done]] = vals[done]
            if np.all(done):
                break
            remaining = np.flatnonzero(~done)
            if sub is not self or len(active) != n:
                self._scatter(active, sub)   # persist consumed state
            active = active[remaining]
            sub = self._gather(active)
        if sub is not self:
            self._scatter(active, sub)
        return out

    def normal(self, scale, mask: Optional[np.ndarray] = None) -> np.ndarray:
        """One ``Generator.normal(0.0, scale)`` per lane (``loc + scale·z``
        with ``loc = 0.0``, matching numpy's ``random_normal`` exactly —
        including the ``0.0 + (-0.0)`` normalisation)."""
        z = self.standard_normal(mask)
        return 0.0 + np.asarray(scale, dtype=np.float64) * z

    def normal_block(self, scale, counts) -> np.ndarray:
        """``[N, M]`` padded normals: lane ``i`` equals
        ``default_rng(seed_i).normal(0.0, scale_i, size=counts[i])``.

        Normal draws consume a variable number of words (ziggurat
        rejections), so the block walks column-by-column with per-lane
        masks — each column is one lock-step vectorized draw.
        """
        counts = np.asarray(counts, dtype=np.int64)
        m = int(counts.max()) if counts.size else 0
        out = np.zeros((self.n_lanes, m))
        scale = np.asarray(scale, dtype=np.float64)
        for j in range(m):
            mask = counts > j
            out[:, j] = np.where(mask, self.normal(scale, mask), 0.0)
        return out

    def standard_exponential(self, mask: Optional[np.ndarray] = None
                             ) -> np.ndarray:
        """One ``Generator.standard_exponential()`` per lane."""
        n = self.n_lanes
        out = np.zeros(n)
        active = np.arange(n) if mask is None else np.flatnonzero(mask)
        while len(active):
            sub = self._gather(active)
            rr = sub._next_raw() >> _U64(3)
            idx = (rr & _U64(0xff)).astype(np.int64)
            rv = rr >> _U64(8)
            x = rv.astype(np.float64) * EXP_WE[idx]
            accept = rv < EXP_KE[idx]
            done = accept.copy()
            vals = np.where(accept, x, 0.0)
            tail = ~accept & (idx == 0)
            if np.any(tail):
                u = sub._next_double(tail)
                t_idx = np.flatnonzero(tail)
                lt = np.zeros(len(u))
                lt[t_idx] = [math.log1p(-u[t]) for t in t_idx]
                vals = np.where(tail, EXP_R - lt, vals)
                done |= tail
            wedge = ~accept & (idx > 0)
            if np.any(wedge):
                u = sub._next_double(wedge)
                ok = wedge & (((EXP_FE[idx - 1] - EXP_FE[idx]) * u
                               + EXP_FE[idx]) < np.exp(-x))
                vals = np.where(ok, x, vals)
                done |= ok
            self._scatter(active, sub)
            out[active[done]] = vals[done]
            active = active[~done]
        return out

    def exponential_block(self, scale, counts) -> np.ndarray:
        """``[N, M]`` padded exponentials: lane ``i`` equals
        ``default_rng(seed_i).exponential(scale_i, size=counts[i])``."""
        counts = np.asarray(counts, dtype=np.int64)
        m = int(counts.max()) if counts.size else 0
        out = np.zeros((self.n_lanes, m))
        scale = np.asarray(scale, dtype=np.float64)
        for j in range(m):
            mask = counts > j
            z = self.standard_exponential(mask)
            out[:, j] = np.where(mask, scale * z, 0.0)
        return out

    # -- poisson ----------------------------------------------------------
    def poisson(self, lam: Union[float, np.ndarray],
                mask: Optional[np.ndarray] = None) -> np.ndarray:
        """One ``Generator.poisson(lam)`` per lane; ``lam`` scalar or [N].

        Replays numpy's ``random_poisson`` dispatch per lane: the
        uniform-product count method below λ = 10, PTRS transformed
        rejection at λ ≥ 10, zero at λ = 0 (no consumption).
        """
        n = self.n_lanes
        lam = np.broadcast_to(np.asarray(lam, dtype=np.float64), (n,))
        out = np.zeros(n, dtype=np.int64)
        base = np.ones(n, dtype=bool) if mask is None else mask.astype(bool)

        mult = base & (lam > 0) & (lam < 10)
        if np.any(mult):
            idx = np.flatnonzero(mult)
            sub = self._gather(idx)
            lam_s = lam[idx]
            # exp(-lam) through libm when lam is one repeated value (the
            # common scalar-λ call); ufunc exp otherwise
            if np.all(lam_s == lam_s[0]):
                enlam = np.full(len(idx), math.exp(-float(lam_s[0])))
            else:
                enlam = np.exp(-lam_s)
            X = np.zeros(len(idx), dtype=np.int64)
            prod = np.ones(len(idx))
            need = np.ones(len(idx), dtype=bool)
            while np.any(need):
                u = sub._next_double(need)
                prod = np.where(need, prod * u, prod)
                cont = need & (prod > enlam)
                X = np.where(cont, X + 1, X)
                need = cont
            self._scatter(idx, sub)
            out[idx] = X

        ptrs = base & (lam >= 10)
        if np.any(ptrs):
            idx = np.flatnonzero(ptrs)
            sub = self._gather(idx)
            out[idx] = _poisson_ptrs(sub, lam[idx])
            self._scatter(idx, sub)
        return out


def _loggam(x: np.ndarray) -> np.ndarray:
    """Vectorized replica of numpy's ``random_loggam`` (Stirling series
    with pull-up below 7), matching the C evaluation op-for-op."""
    x = np.asarray(x, dtype=np.float64)
    n = np.where(x <= 7.0, (7.0 - x).astype(np.int64), 0)
    x0 = x + n
    x2 = (1.0 / x0) * (1.0 / x0)
    gl0 = np.full(x.shape, _LOGGAM_A[9])
    for k in range(8, -1, -1):
        gl0 = gl0 * x2 + _LOGGAM_A[k]
    gl = (gl0 / x0 + 0.5 * _LOG_2PI + (x0 - 0.5) * np.log(x0) - x0)
    for k in range(1, 7):
        m = (x <= 7.0) & (k <= n)
        gl = np.where(m, gl - np.log(np.where(m, x0 - 1.0, 1.0)), gl)
        x0 = np.where(m, x0 - 1.0, x0)
    return np.where((x == 1.0) | (x == 2.0), 0.0, gl)


def _poisson_ptrs(sub: VecStreams, lam: np.ndarray) -> np.ndarray:
    """PTRS (transformed rejection) sampler on a gathered lane subset."""
    slam = np.sqrt(lam)
    loglam = np.log(lam)
    b = 0.931 + 2.53 * slam
    a = -0.059 + 0.02483 * b
    invalpha = 1.1239 + 1.1328 / (b - 3.4)
    vr = 0.9277 - 3.6224 / (b - 2)
    n = len(lam)
    out = np.zeros(n, dtype=np.int64)
    need = np.ones(n, dtype=bool)
    while np.any(need):
        U = sub._next_double(need) - 0.5
        V = sub._next_double(need)
        us = 0.5 - np.abs(U)
        k = np.floor((2.0 * a / us + b) * U + lam + 0.43).astype(np.int64)
        fast = need & (us >= 0.07) & (V <= vr)
        out = np.where(fast, k, out)
        need &= ~fast
        retry = need & ((k < 0) | ((us < 0.013) & (V > us)))
        test = need & ~retry
        if np.any(test):
            with np.errstate(divide="ignore"):
                lhs = (np.log(V) + np.log(invalpha)
                       - np.log(a / (us * us) + b))
            rhs = -lam + k * loglam - _loggam((k + 1).astype(np.float64))
            ok = test & (lhs <= rhs)
            out = np.where(ok, k, out)
            need &= ~ok
    return out
