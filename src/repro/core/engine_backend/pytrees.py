"""Array containers shared by every execution backend.

:class:`TimelineArrays` is the padded ``[R, S]`` form of a
:class:`~repro.core.ground_truth.TimelineBank` — a plain ``NamedTuple`` of
arrays, which makes it a JAX pytree for free: it can be passed straight
into ``jax.jit``-compiled kernels (leaves trace as ``jnp`` arrays) while
staying a zero-cost tuple of ``np.ndarray`` views on the NumPy path.

The container carries no behaviour on purpose: backends implement the
kernels as pure functions over these arrays, so the same signature works
for NumPy, JAX, and any future array namespace.
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np


class TimelineArrays(NamedTuple):
    """Padded piecewise-constant traces: ``R`` rows of up to ``S`` segments.

    ``edges`` is ``[R, S+1]`` (non-decreasing per row, padding repeats the
    final valid edge), ``powers`` ``[R, S]`` (padding holds the row's idle
    power), ``idle_w`` and ``n_segs`` are ``[R]``.  Invariants are
    normalised by :class:`~repro.core.ground_truth.TimelineBank`; backends
    may assume them.
    """

    edges: np.ndarray
    powers: np.ndarray
    idle_w: np.ndarray
    n_segs: np.ndarray

    @property
    def n_rows(self) -> int:
        return self.edges.shape[0]

    @property
    def t_start(self) -> np.ndarray:
        return self.edges[:, 0]

    @property
    def t_end(self) -> np.ndarray:
        return self.edges[:, -1]


class ReadingSchedule(NamedTuple):
    """A fleet's published-reading schedule as padded ``[N, M]`` arrays.

    ``ticks`` holds every device's publication instants
    (``phase + T * k``, leading/trailing slots masked rather than
    filtered); ``first``/``last`` are each device's first/last valid slot,
    ``k0`` the tick index of slot 0.  Together with ``phase`` and
    ``update_period_s`` this is everything a kernel needs to map a
    wall-clock instant to the reading slot that covers it.
    """

    ticks: np.ndarray
    first: np.ndarray
    last: np.ndarray
    k0: np.ndarray
    phase: np.ndarray
    update_period_s: np.ndarray


class PollGrid(NamedTuple):
    """A uniform ``nvidia-smi -lms``-style poll grid shared by a fleet.

    ``t0`` and ``period_s`` are scalars; ``t1`` is per-device (each scalar
    sensor's grid ends with its own trial), so device ``i`` owns poll
    indices ``0 .. floor((t1[i] - t0) / period_s) - 1``.  ``grid_offset``
    shifts the *reported* timestamps (the §5 re-synchronisation step)
    while queries still happen at the true wall-clock instant — a
    scalar, or a per-device [N] array when a fleet mixes averaging
    windows (each sensor class re-synchronises by its own window).
    """

    t0: float
    t1: np.ndarray
    period_s: float
    grid_offset: "float | np.ndarray" = 0.0
