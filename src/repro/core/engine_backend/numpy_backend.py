"""NumPy implementation of the fleet-engine kernels.

This module is the reference semantics: every function is a pure array
function (no hidden state, no RNG) extracted from the original
``fleet_engine`` / ``ground_truth`` hot paths.  The JAX backend
(:mod:`repro.core.engine_backend.jax_backend`) reimplements the same
signatures with ``jax.jit`` + ``vmap``; parity is pinned by
``tests/test_engine_backend.py`` to within one reporting quantum.

Kernels
-------
* :func:`searchsorted_rows`     — row-wise exact binary search
* :func:`timeline_integral`     — exact per-row ∫P dt (idle outside coverage)
* :func:`boxcar_means`          — batched trailing-window means
* :func:`estimation_means`      — activity-proxy means (boxcar × model gain)
* :func:`log_filter`            — first-order-filter segment scan
* :func:`poll_counts`           — closed-form poll counting for
  ``integrate_polled`` (how many uniform poll instants land in each
  reading interval, plus the partial final step)
* :func:`step_integrate`        — batched rectangle/trapezoid
  integration of sampled reading series (the single source of truth
  shared by ``meter._integrate_readings`` and the streaming monitor)
* :func:`stream_ingest`         — the streaming monitor's hot path:
  one slab of (device, t, reading) samples folded into per-device
  online accumulators (energy, windowed energy, run tracking)
* :func:`stream_ingest_grid`    — the rectangular fast path of
  ``stream_ingest``: D devices × one shared strictly-increasing time
  axis, all accumulators row-wise (no sorting or segmented reductions)

No module in this file imports from the rest of :mod:`repro` — backends
sit at the bottom of the dependency graph so ``ground_truth`` and
``fleet_engine`` can both build on them.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

from repro.core.engine_backend.pytrees import (PollGrid, ReadingSchedule,
                                               TimelineArrays)

name = "numpy"

_FAR = np.iinfo(np.int64).max // 2


def searchsorted_rows(a: np.ndarray, v: np.ndarray,
                      side: str = "right") -> np.ndarray:
    """Row-wise ``np.searchsorted``: sorted rows ``a`` [R, S] against query
    rows ``v`` [G, M], where R == G or R == 1 (row broadcast).

    A fixed-iteration vectorised binary search with *exact* comparisons —
    no offset/flattening tricks that would perturb float values — so the
    result is bitwise what ``np.searchsorted(a[i], v[i], side)`` returns
    per row.  Cost is ``ceil(log2 S)`` gather passes over [G, M].
    """
    if side not in ("left", "right"):
        raise ValueError(f"bad side '{side}'")
    a = np.asarray(a)
    v = np.asarray(v)
    r, s = a.shape
    g = v.shape[0]
    if r not in (1, g):
        raise ValueError(f"cannot broadcast {r} rows against {g} queries")
    if r == 1 and g > 1:
        a = np.broadcast_to(a, (g, s))
    lo = np.zeros(v.shape, dtype=np.int64)
    hi = np.full(v.shape, s, dtype=np.int64)
    for _ in range(int(np.ceil(np.log2(max(s, 2)))) + 1):
        active = lo < hi
        if not np.any(active):
            break
        mid = (lo + hi) >> 1
        # mid < s wherever active; the clip only feeds settled lanes
        amid = np.take_along_axis(a, np.minimum(mid, s - 1), axis=1)
        go = (amid <= v) if side == "right" else (amid < v)
        lo = np.where(active & go, mid + 1, lo)
        hi = np.where(active & ~go, mid, hi)
    return lo


def _broadcast_rows(tl: TimelineArrays, g: int) -> TimelineArrays:
    """Broadcast a single-row bank to ``g`` query rows (views, no copy)."""
    r = tl.n_rows
    if r == g:
        return tl
    if r != 1:
        raise ValueError(f"{g} query rows for {r} timeline rows")
    return TimelineArrays(
        np.broadcast_to(tl.edges, (g, tl.edges.shape[1])),
        np.broadcast_to(tl.powers, (g, tl.powers.shape[1])),
        np.broadcast_to(tl.idle_w, (g,)),
        np.broadcast_to(tl.n_segs, (g,)))


def cum_energy(tl: TimelineArrays) -> np.ndarray:
    """Per-row cumulative segment energy [R, S+1] (zero at the first edge)."""
    seg = tl.powers * np.diff(tl.edges, axis=1)
    return np.concatenate(
        [np.zeros((tl.n_rows, 1)), np.cumsum(seg, axis=1)], axis=1)


def timeline_integral(tl: TimelineArrays, t0: np.ndarray,
                      t1: np.ndarray) -> np.ndarray:
    """Exact per-row ∫P_i dt over [t0_i, t1_i] [G, M]; idle outside
    coverage.  ``tl`` has G rows, or 1 row broadcast against G."""
    t0 = np.asarray(t0, dtype=np.float64)
    t1 = np.asarray(t1, dtype=np.float64)
    g = t0.shape[0]
    cum = cum_energy(tl)            # on the R stored rows, then broadcast
    tl = _broadcast_rows(tl, g)
    e, p, idle, ns = tl
    if cum.shape[0] != g:
        cum = np.broadcast_to(cum, (g, cum.shape[1]))
    first = e[:, 0][:, None]
    last = e[:, -1][:, None]
    hi_idx = np.maximum(ns - 1, 0)[:, None]

    def eval_I(t):
        tc = np.clip(t, first, last)
        idx = np.clip(searchsorted_rows(e, tc, "right") - 1, 0, hi_idx)
        inner = (np.take_along_axis(cum, idx, axis=1)
                 + np.take_along_axis(p, idx, axis=1)
                 * (tc - np.take_along_axis(e, idx, axis=1)))
        before = np.minimum(t - first, 0.0) * idle[:, None]
        after = np.maximum(t - last, 0.0) * idle[:, None]
        return inner + before + after

    return eval_I(t1) - eval_I(t0)


def boxcar_means(tl: TimelineArrays, t0: np.ndarray,
                 t1: np.ndarray) -> np.ndarray:
    """Batched trailing-window means: ∫P dt / (t1 - t0) over [G, M]
    windows — the boxcar transient's raw reading."""
    t0 = np.asarray(t0, dtype=np.float64)
    t1 = np.asarray(t1, dtype=np.float64)
    dt = np.maximum(t1 - t0, 1e-12)
    return timeline_integral(tl, t0, t1) / dt


def estimation_means(tl: TimelineArrays, t0: np.ndarray, t1: np.ndarray,
                     model_gain: np.ndarray) -> np.ndarray:
    """Activity-proxy transient: the true period mean seen through a crude
    per-device activity model (``model_gain`` [G])."""
    return boxcar_means(tl, t0, t1) * np.asarray(model_gain)[:, None]


def log_filter(tl: TimelineArrays, ticks: np.ndarray,
               tau: np.ndarray) -> np.ndarray:
    """Batched first-order filter y' = (P - y)/tau for G devices.

    The scalar ``OnboardSensor._filtered_at`` walks the piecewise-constant
    segments in a per-device Python loop; here one scan advances a vector
    of G filter states per step.  With a shared timeline (single-row bank)
    the loop length is the number of timeline edges — independent of fleet
    size; with per-device rows the scan walks each row's own padded edge
    sequence, masking the zero-width padding steps so the state carries
    through unchanged.  Before the first real edge the state is exactly
    ``idle_w`` (the ``t_lo`` padding only ever covers idle), so readings
    are bitwise identical to the scalar filter for any padding choice.
    """
    g, _ = ticks.shape
    tau = np.asarray(tau, dtype=np.float64)
    t_lo = (min(float(np.min(ticks)), float(np.min(tl.t_start)))
            - 5.0 * float(np.max(tau)))
    t_hi = max(float(np.max(ticks)), float(np.max(tl.t_end))) + 1e-9
    r = tl.n_rows
    ext_e = np.concatenate([np.full((r, 1), t_lo), tl.edges,
                            np.full((r, 1), t_hi)], axis=1)
    ext_p = np.concatenate([tl.idle_w[:, None], tl.powers,
                            tl.idle_w[:, None]], axis=1)
    n_seg = ext_p.shape[1]
    dts = np.diff(ext_e, axis=1)

    y = np.empty((g, n_seg + 1))
    y[:, 0] = np.broadcast_to(tl.idle_w, (g,))
    for i in range(n_seg):
        dt = dts[:, i]
        sp = ext_p[:, i]
        step = sp + (y[:, i] - sp) * np.exp(-dt / tau)
        y[:, i + 1] = np.where(dt > 0, step, y[:, i])

    idx = np.clip(searchsorted_rows(ext_e, ticks, side="right") - 1,
                  0, n_seg - 1)
    y_at = np.take_along_axis(y, idx, axis=1)
    sp_at = np.take_along_axis(np.broadcast_to(ext_p, (g, n_seg)), idx,
                               axis=1)
    e_at = np.take_along_axis(np.broadcast_to(ext_e, (g, n_seg + 1)), idx,
                              axis=1)
    return sp_at + (y_at - sp_at) * np.exp(-(ticks - e_at) / tau[:, None])


def poll_counts(sched: ReadingSchedule, grid: PollGrid, a: np.ndarray,
                b: np.ndarray) -> Tuple[np.ndarray, np.ndarray,
                                        np.ndarray, np.ndarray]:
    """Closed-form poll counting over uniform grids: the core of
    ``SensorBank.integrate_polled``.

    Because the poll grid is uniform and the published readings are a
    step function over the tick grid, the number of poll instants falling
    inside each reading interval has a closed form — no [N, n_poll]
    reading matrix is ever materialised.  Returns

    * ``counts`` [N, M]  — poll instants covered by each reading slot
      within the selected index range,
    * ``slot_b`` [N]     — the reading slot current at the final selected
      poll instant (for the partial last step),
    * ``tail_dt`` [N]    — ``b - r(j1)``, the partial step the final poll
      instant integrates over,
    * ``nonempty`` [N]   — whether any poll instant landed in [a, b].

    The caller contracts ``period · Σ_k v_k · counts_k + v_{slot_b} ·
    tail_dt`` (zeroed where empty), which matches
    ``meter._integrate_readings`` on the equivalent polled series.
    """
    a = np.asarray(a, dtype=np.float64)
    b = np.asarray(b, dtype=np.float64)
    n = a.shape[0]
    period_s = grid.period_s
    # per-device poll ends reproduce each scalar sensor's finite grid
    m_i = np.floor((np.asarray(grid.t1, dtype=np.float64) - grid.t0)
                   / period_s).astype(np.int64)

    def q(idx):
        # true wall-clock query instant, same expression as poll()
        return grid.t0 + period_s * idx

    def r(idx):
        # reported (possibly re-synchronised) poll timestamp
        return (grid.t0 + period_s * idx) + grid.grid_offset

    # per-device selected index range [j0, j1] on the shared grid,
    # settling FP boundary cases against the actual grid values
    j0 = np.ceil((a - grid.grid_offset - grid.t0) / period_s).astype(np.int64)
    j1 = np.floor((b - grid.grid_offset - grid.t0) / period_s).astype(np.int64)
    for _ in range(2):
        j0 = np.where(r(j0 - 1) >= a, j0 - 1, j0)
        j0 = np.where(r(j0) < a, j0 + 1, j0)
        j1 = np.where(r(j1 + 1) <= b, j1 + 1, j1)
        j1 = np.where(r(j1) > b, j1 - 1, j1)
    j0 = np.maximum(j0, 0)
    j1 = np.minimum(j1, m_i - 1)

    ticks = sched.ticks
    m = ticks.shape[1]
    slot = np.arange(m)[None, :]
    # lo[k]: first poll index whose reading is slot k, i.e. smallest j
    # with q(j) >= tick_k (two FP settling passes, like query())
    lo = np.ceil((ticks - grid.t0) / period_s).astype(np.int64)
    for _ in range(2):
        lo = np.where(q(lo - 1) >= ticks, lo - 1, lo)
        lo = np.where(q(lo) < ticks, lo + 1, lo)
    hi = np.concatenate([lo[:, 1:] - 1, np.full((n, 1), _FAR)], axis=1)
    # query() clamps to [first, last]: the first reading extends back to
    # -inf, the last forward to +inf
    lo = np.where(slot == sched.first[:, None], np.int64(0), lo)
    hi = np.where(slot == sched.last[:, None], _FAR, hi)
    counts = (np.minimum(hi, (j1 - 1)[:, None])
              - np.maximum(lo, j0[:, None]) + 1)
    valid = (slot >= sched.first[:, None]) & (slot <= sched.last[:, None])
    counts = np.where(valid, np.maximum(counts, 0), 0)

    slot_b = query_slots(sched, q(j1.astype(np.float64))[:, None])[:, 0]
    tail_dt = b - r(j1.astype(np.float64))
    return counts, slot_b, tail_dt, j1 >= j0


def err_moments(e: np.ndarray) -> Tuple[int, float, float, float, float]:
    """One slab's error-moment reduction for the streaming fleet audit:
    ``(count, mean, M2, mean_abs, max_abs)``.  Slabs merge by Chan's
    parallel-Welford update (:class:`repro.core.fleet_engine.\
StreamingMoments`), so a chunked audit never reduces over all N errors
    at once."""
    e = np.asarray(e, dtype=np.float64)
    n = int(e.size)
    if n == 0:
        return 0, 0.0, 0.0, 0.0, 0.0
    mean = float(np.mean(e))
    m2 = float(np.sum((e - mean) ** 2))
    ae = np.abs(e)
    return n, mean, m2, float(np.mean(ae)), float(np.max(ae))


def step_integrate(ts: np.ndarray, vals: np.ndarray, t0: np.ndarray,
                   t1: np.ndarray, trapezoid: bool = False) -> np.ndarray:
    """Batched ``meter._integrate_readings``: integrate each row's sampled
    reading series over ``[t0_i, t1_i]``.

    ``ts`` is [N, M] per-row *non-decreasing* sample times — pad unused
    trailing slots with ``+inf`` — and ``vals`` [N, M] the readings.
    Samples with ``t0 <= ts <= t1`` contribute; sample ``j`` holds until
    the next sample (the last selected one holds to ``t1``), exactly the
    scalar reference's rectangle rule.  ``trapezoid=True`` replaces each
    interval's held value with the two endpoints' mean (the final partial
    step stays rectangular — there is no sample beyond it).  Rows whose
    window selects no sample integrate to 0.

    Selection is two row-wise exact binary searches, the interior sum a
    prefix-sum difference, so the whole thing is O(N·M) with no Python
    loop — this is the one rectangle/trapezoid implementation shared by
    the offline §5 protocol and the online streaming monitor.
    """
    ts = np.asarray(ts, dtype=np.float64)
    vals = np.asarray(vals, dtype=np.float64)
    t0 = np.asarray(t0, dtype=np.float64)
    t1 = np.asarray(t1, dtype=np.float64)
    n, m = ts.shape
    if m == 0:      # no samples at all: every window integrates to 0
        return np.zeros(n)
    j0 = searchsorted_rows(ts, t0[:, None], "left")[:, 0]
    j1 = searchsorted_rows(ts, t1[:, None], "right")[:, 0] - 1

    nxt_finite = np.isfinite(ts[:, 1:])
    # padding slots are +inf; mask the operands (not just the result) so
    # no inf - inf is ever evaluated
    dt = (np.where(nxt_finite, ts[:, 1:], 0.0)
          - np.where(nxt_finite, ts[:, :-1], 0.0))
    if trapezoid:
        dens = 0.5 * (vals[:, :-1] + np.where(nxt_finite, vals[:, 1:], 0.0))
    else:
        dens = vals[:, :-1]
    cum = np.concatenate([np.zeros((n, 1)), np.cumsum(dens * dt, axis=1)],
                         axis=1)

    j0c = np.clip(j0, 0, m - 1)[:, None]
    j1c = np.clip(j1, 0, m - 1)[:, None]
    core = (np.take_along_axis(cum, j1c, axis=1)
            - np.take_along_axis(cum, j0c, axis=1))[:, 0]
    tail = (np.take_along_axis(vals, j1c, axis=1)[:, 0]
            * (t1 - np.take_along_axis(ts, j1c, axis=1)[:, 0]))
    nonempty = (j1 >= j0) & (j0 < m)
    return np.where(nonempty, core + tail, 0.0)


def stream_ingest(t: np.ndarray, v: np.ndarray, seg: np.ndarray,
                  first: np.ndarray, start_idx: np.ndarray,
                  end_idx: np.ndarray, prev_t: np.ndarray,
                  prev_v: np.ndarray, has_prev: np.ndarray,
                  run_t: np.ndarray, n_changes: np.ndarray,
                  gain: np.ndarray, offset: np.ndarray,
                  tshift: np.ndarray, win_a: np.ndarray,
                  win_b: np.ndarray, max_hold: np.ndarray,
                  env_lo: np.ndarray, env_hi: np.ndarray,
                  trapezoid: bool = False) -> Tuple:
    """One slab of the streaming monitor's hot path.

    Inputs are ``K`` accepted samples sorted by (device, time) and
    compacted to ``U`` per-slab device groups: ``seg`` [K] is the group
    id (0..U-1, contiguous and ascending), ``first`` [K] marks each
    group's first sample, ``start_idx``/``end_idx`` [U] are the group
    boundary positions (host-computed so the jax twin stays static-shape).
    The remaining [U] vectors are the gathered per-device monitor state
    (``prev_*``, ``has_prev``, ``run_t``, ``n_changes`` — ``run_t``
    pre-initialised to the slab's first sample time for brand-new
    devices) and correction parameters: ``gain``/``offset`` invert the
    calibrated transform, ``tshift`` re-synchronises reported timestamps
    (a reading at ``t`` covers ``[t - tshift, t]``), ``win_a``/``win_b``
    bound each device's registered measurement window, ``max_hold`` caps
    how long one reading may be extrapolated across a sampling gap
    (``inf`` = plain rectangle), ``env_lo``/``env_hi`` the calibrated
    plausibility envelope.

    Returns, per group [U]: ``new_t, new_v, new_run_t, new_n_changes,
    counts, d_energy, d_energy_corr, d_win, d_win_corr, sum_vc, n_out``
    and, per sample [K]: ``cum_e, cum_ec`` (within-slab inclusive energy
    prefixes for ring snapshots), ``vc`` (corrected readings),
    ``run_dur, run_rec`` (completed-run durations and whether each is a
    *complete* run — bounded by a reading change on both sides — for the
    online update-period histogram).
    """
    t = np.asarray(t, dtype=np.float64)
    v = np.asarray(v, dtype=np.float64)
    k = t.shape[0]
    u = prev_t.shape[0]
    idx = np.arange(k)

    # previous sample within the slab, or the stored state at group starts
    shift_t = np.concatenate([[0.0], t[:-1]])
    shift_v = np.concatenate([[0.0], v[:-1]])
    pt = np.where(first, prev_t[seg], shift_t)
    pv = np.where(first, prev_v[seg], shift_v)
    has = np.where(first, has_prev[seg], True)

    g = gain[seg]
    off = offset[seg]
    vc = (v - off) / g
    pvc = (pv - off) / g
    dt = t - pt
    hold = np.minimum(dt, max_hold[seg])
    dens_r = 0.5 * (pv + v) if trapezoid else pv
    dens_c = 0.5 * (pvc + vc) if trapezoid else pvc
    inc = np.where(has, dens_r * hold, 0.0)
    inc_c = np.where(has, dens_c * hold, 0.0)

    # within-group inclusive energy prefixes (global cumsum re-based at
    # each group's start), so ring snapshots see exact running totals
    cs = np.cumsum(inc)
    cum_e = cs - (cs[start_idx] - inc[start_idx])[seg]
    csc = np.cumsum(inc_c)
    cum_ec = csc - (csc[start_idx] - inc_c[start_idx])[seg]
    d_energy = cum_e[end_idx]
    d_energy_corr = cum_ec[end_idx]

    # registered measurement windows: the §5 naive/corrected protocol's
    # [a, b] clipping, sample-by-sample (corrected uses reported times,
    # i.e. raw times shifted back by the averaging window)
    a = win_a[seg]
    b = win_b[seg]
    w_inc = np.where(has & (pt >= a),
                     dens_r * np.maximum(np.minimum(pt + hold, b) - pt, 0.0),
                     0.0)
    pts = pt - tshift[seg]
    w_inc_c = np.where(has & (pts >= a),
                       dens_c * np.maximum(np.minimum(pts + hold, b) - pts,
                                           0.0),
                       0.0)
    d_win = np.bincount(seg, weights=w_inc, minlength=u)
    d_win_corr = np.bincount(seg, weights=w_inc_c, minlength=u)

    # run tracking: a reading change closes the run started at the
    # previous change; only runs bounded by changes on *both* sides are
    # recorded (microbench's complete-runs rule, online)
    change = has & (v != pv)
    ci = np.where(change, idx, -1)
    acc = np.maximum.accumulate(ci)
    acc_excl = np.concatenate([[-1], acc[:-1]])
    gstart = start_idx[seg]
    prev_chg = np.where(acc_excl >= gstart, acc_excl, -1)
    run_start = np.where(prev_chg >= 0, t[np.maximum(prev_chg, 0)],
                         run_t[seg])
    run_dur = np.where(change, t - run_start, 0.0)
    cchg = np.cumsum(change)
    chg_before_slab = cchg - (cchg[start_idx]
                              - change[start_idx])[seg] - change
    run_rec = change & (n_changes[seg] + chg_before_slab >= 1)

    new_run_t = np.where(acc[end_idx] >= start_idx,
                         t[np.maximum(acc[end_idx], 0)], run_t)
    new_n_changes = n_changes + np.bincount(
        seg, weights=change.astype(np.float64), minlength=u).astype(np.int64)

    counts = np.bincount(seg, minlength=u).astype(np.int64)
    sum_vc = np.bincount(seg, weights=vc, minlength=u)
    out = ((vc < env_lo[seg]) | (vc > env_hi[seg])).astype(np.float64)
    n_out = np.bincount(seg, weights=out, minlength=u).astype(np.int64)

    return (t[end_idx], v[end_idx], new_run_t, new_n_changes, counts,
            d_energy, d_energy_corr, d_win, d_win_corr, sum_vc, n_out,
            cum_e, cum_ec, vc, run_dur, run_rec)


def stream_ingest_grid(ts: np.ndarray, v: np.ndarray, prev_t: np.ndarray,
                       prev_v: np.ndarray, has_prev: np.ndarray,
                       run_t: np.ndarray, n_changes: np.ndarray,
                       gain: np.ndarray, offset: np.ndarray,
                       tshift: np.ndarray, win_a: np.ndarray,
                       win_b: np.ndarray, max_hold: np.ndarray,
                       env_lo: np.ndarray, env_hi: np.ndarray,
                       trapezoid: bool = False) -> Tuple:
    """Rectangular fast path of :func:`stream_ingest`: ``D`` devices share
    one strictly-increasing time axis ``ts`` [M] with readings ``v``
    [D, M] (the shape tick-grid emitters such as
    ``SensorBank.iter_poll_slabs(grid=True)`` produce natively).

    Semantically this is ``stream_ingest`` on the equivalent flattened
    device-major slab where every device contributes every tick — but
    with no sorting, no group compaction and no segmented reductions:
    every accumulator is a row-wise cumulative sum or reduction over the
    [D, M] block, so the per-sample cost is a handful of vector ops.
    The per-device state/parameter vectors are all [D]; ``run_t`` must be
    pre-initialised to ``ts[0]`` for devices without history, exactly as
    the generic kernel's caller does.

    Returns, per device [D]: ``new_v, new_run_t, new_n_changes,
    d_energy, d_energy_corr, d_win, d_win_corr, sum_vc, sum_vc2,
    sum_abs_vc, max_abs_vc, n_out`` and, per sample [D, M]: ``cum_e,
    cum_ec, run_dur, run_rec``.  (``new_t`` is just ``ts[-1]`` and
    ``counts`` is ``M`` — the caller computes both; the extra corrected-
    reading moment sums replace the flattened ``vc`` vector, so label
    statistics merge from [D] reductions instead of [D·M] samples.)
    """
    ts = np.asarray(ts, dtype=np.float64)
    v = np.asarray(v, dtype=np.float64)
    d, m = v.shape
    if m == 0:      # empty slab: state passes through untouched
        z = np.zeros((d, 0))
        return (prev_v.copy(), run_t.copy(), n_changes.copy(),
                np.zeros(d), np.zeros(d), np.zeros(d), np.zeros(d),
                np.zeros(d), np.zeros(d), np.zeros(d), np.zeros(d),
                np.zeros(d, dtype=np.int64), z, z, z,
                np.zeros((d, 0), dtype=bool))

    # previous sample per column: the stored state at column 0, the
    # neighbouring column elsewhere
    pt = np.empty((d, m))
    pt[:, 0] = prev_t
    pt[:, 1:] = ts[:-1][None, :]
    pv = np.concatenate([prev_v[:, None], v[:, :-1]], axis=1)
    has = np.ones((d, m), dtype=bool)
    has[:, 0] = has_prev

    g = gain[:, None]
    off = offset[:, None]
    vc = (v - off) / g
    pvc = (pv - off) / g
    dt = ts[None, :] - pt
    hold = np.minimum(dt, max_hold[:, None])
    dens_r = 0.5 * (pv + v) if trapezoid else pv
    dens_c = 0.5 * (pvc + vc) if trapezoid else pvc
    inc = np.where(has, dens_r * hold, 0.0)
    inc_c = np.where(has, dens_c * hold, 0.0)
    cum_e = np.cumsum(inc, axis=1)
    cum_ec = np.cumsum(inc_c, axis=1)

    a = win_a[:, None]
    b = win_b[:, None]
    w_inc = np.where(has & (pt >= a),
                     dens_r * np.maximum(np.minimum(pt + hold, b) - pt, 0.0),
                     0.0)
    pts = pt - tshift[:, None]
    w_inc_c = np.where(has & (pts >= a),
                       dens_c * np.maximum(np.minimum(pts + hold, b) - pts,
                                           0.0),
                       0.0)

    # run tracking, row-wise: the previous change within the row (or the
    # carried ``run_t``) opens the run a change closes
    change = has & (v != pv)
    cols = np.arange(m)[None, :]
    ci = np.where(change, cols, -1)
    acc = np.maximum.accumulate(ci, axis=1)
    acc_excl = np.concatenate([np.full((d, 1), -1), acc[:, :-1]], axis=1)
    run_start = np.where(acc_excl >= 0, ts[np.maximum(acc_excl, 0)],
                         run_t[:, None])
    run_dur = np.where(change, ts[None, :] - run_start, 0.0)
    cchg = np.cumsum(change, axis=1)
    run_rec = change & (n_changes[:, None] + (cchg - change) >= 1)

    last = acc[:, -1]
    new_run_t = np.where(last >= 0, ts[np.maximum(last, 0)], run_t)
    new_n_changes = n_changes + cchg[:, -1]

    av = np.abs(vc)
    out = (vc < env_lo[:, None]) | (vc > env_hi[:, None])
    return (v[:, -1].copy(), new_run_t, new_n_changes,
            cum_e[:, -1].copy(), cum_ec[:, -1].copy(),
            np.sum(w_inc, axis=1), np.sum(w_inc_c, axis=1),
            np.sum(vc, axis=1), np.sum(vc * vc, axis=1),
            np.sum(av, axis=1), np.max(av, axis=1),
            np.sum(out, axis=1).astype(np.int64),
            cum_e, cum_ec, run_dur, run_rec)


def query_slots(sched: ReadingSchedule, tq: np.ndarray) -> np.ndarray:
    """Reading slot current at wall-clock times ``tq`` [N, K]: the
    arithmetic index (same ``phase + T·k`` expression that built the
    grid), settled against the stored tick values and clamped to each
    device's valid range — identical to ``SensorBank.query``'s indexing.
    """
    T = sched.update_period_s[:, None]
    phase = sched.phase[:, None]
    m = sched.ticks.shape[1]
    j = np.floor((tq - phase) / T).astype(np.int64) - sched.k0[:, None]
    j = np.clip(j, 0, m - 1)
    # the arithmetic index can be off by one ulp at tick boundaries;
    # settle it against the actual stored tick values (two passes are
    # enough: the estimate is within ±1 of the true slot)
    for _ in range(2):
        tj = np.take_along_axis(sched.ticks, j, axis=1)
        j = np.where((tj > tq) & (j > 0), j - 1, j)
    for _ in range(2):
        jn = np.minimum(j + 1, m - 1)
        tn = np.take_along_axis(sched.ticks, jn, axis=1)
        j = np.where((tn <= tq) & (jn > j), jn, j)
    return np.clip(j, sched.first[:, None], sched.last[:, None])


def snapshot_energy_at(tq: np.ndarray, last_t: np.ndarray,
                       dens: np.ndarray, has: np.ndarray,
                       first_t: np.ndarray, base: np.ndarray,
                       max_hold: np.ndarray, ring_t, ring_dens, ring_base):
    """Batched snapshot-view energy query: energy since first sample at
    ``Q`` instants for all ``N`` devices at once.

    ``tq`` [Q] query instants; ``last_t``/``dens``/``has``/``first_t``/
    ``base``/``max_hold`` [N] are the published snapshot's per-device
    tail state (``dens``/``base`` already in the requested raw/corrected
    flavour); ``ring_t``/``ring_dens``/``ring_base`` [N, R] are the
    snapshot's *sorted* ring view in the same flavour, or ``None`` when
    the ring is disabled.  Returns ``(e, covered)`` [Q, N] with nan
    where an instant predates ring coverage — each row bitwise equal to
    the single-instant query path (the math is elementwise, so the Q
    broadcast changes nothing).
    """
    tq = np.asarray(tq, dtype=np.float64)[:, None]          # [Q, 1]
    dt = tq - last_t[None, :]
    hold = np.minimum(dt, max_hold[None, :])
    live = has[None, :] & (dt >= 0.0)
    e_live = np.where(live, base[None, :] + dens[None, :] * hold, 0.0)
    covered = live | ~has[None, :] | (tq <= first_t[None, :])
    started = has[None, :] & (tq > first_t[None, :])
    e = np.where(started, e_live, 0.0)
    past = started & (tq < last_t[None, :])
    if ring_t is not None and np.any(past):
        rows = np.broadcast_to(tq.T, (ring_t.shape[0], tq.shape[0]))
        j = searchsorted_rows(ring_t, rows, "right") - 1    # [N, Q]
        ok = j >= 0
        jc = np.clip(j, 0, ring_t.shape[1] - 1)
        rt = np.take_along_axis(ring_t, jc, axis=1)
        rd = np.take_along_axis(ring_dens, jc, axis=1)
        rb = np.take_along_axis(ring_base, jc, axis=1)
        hold_p = np.minimum(tq - rt.T, max_hold[None, :])
        # empty ring slots carry t=inf sentinels: 0*inf warns but the
        # result is masked out by sel below
        with np.errstate(invalid="ignore"):
            e_past = rb.T + rd.T * hold_p
        sel = past & ok.T
        e = np.where(sel, e_past, e)
        covered = covered | sel
    return np.where(covered, e, np.nan), covered
