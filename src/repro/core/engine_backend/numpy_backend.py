"""NumPy implementation of the fleet-engine kernels.

This module is the reference semantics: every function is a pure array
function (no hidden state, no RNG) extracted from the original
``fleet_engine`` / ``ground_truth`` hot paths.  The JAX backend
(:mod:`repro.core.engine_backend.jax_backend`) reimplements the same
signatures with ``jax.jit`` + ``vmap``; parity is pinned by
``tests/test_engine_backend.py`` to within one reporting quantum.

Kernels
-------
* :func:`searchsorted_rows`     — row-wise exact binary search
* :func:`timeline_integral`     — exact per-row ∫P dt (idle outside coverage)
* :func:`boxcar_means`          — batched trailing-window means
* :func:`estimation_means`      — activity-proxy means (boxcar × model gain)
* :func:`log_filter`            — first-order-filter segment scan
* :func:`poll_counts`           — closed-form poll counting for
  ``integrate_polled`` (how many uniform poll instants land in each
  reading interval, plus the partial final step)

No module in this file imports from the rest of :mod:`repro` — backends
sit at the bottom of the dependency graph so ``ground_truth`` and
``fleet_engine`` can both build on them.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

from repro.core.engine_backend.pytrees import (PollGrid, ReadingSchedule,
                                               TimelineArrays)

name = "numpy"

_FAR = np.iinfo(np.int64).max // 2


def searchsorted_rows(a: np.ndarray, v: np.ndarray,
                      side: str = "right") -> np.ndarray:
    """Row-wise ``np.searchsorted``: sorted rows ``a`` [R, S] against query
    rows ``v`` [G, M], where R == G or R == 1 (row broadcast).

    A fixed-iteration vectorised binary search with *exact* comparisons —
    no offset/flattening tricks that would perturb float values — so the
    result is bitwise what ``np.searchsorted(a[i], v[i], side)`` returns
    per row.  Cost is ``ceil(log2 S)`` gather passes over [G, M].
    """
    if side not in ("left", "right"):
        raise ValueError(f"bad side '{side}'")
    a = np.asarray(a)
    v = np.asarray(v)
    r, s = a.shape
    g = v.shape[0]
    if r not in (1, g):
        raise ValueError(f"cannot broadcast {r} rows against {g} queries")
    if r == 1 and g > 1:
        a = np.broadcast_to(a, (g, s))
    lo = np.zeros(v.shape, dtype=np.int64)
    hi = np.full(v.shape, s, dtype=np.int64)
    for _ in range(int(np.ceil(np.log2(max(s, 2)))) + 1):
        active = lo < hi
        if not np.any(active):
            break
        mid = (lo + hi) >> 1
        # mid < s wherever active; the clip only feeds settled lanes
        amid = np.take_along_axis(a, np.minimum(mid, s - 1), axis=1)
        go = (amid <= v) if side == "right" else (amid < v)
        lo = np.where(active & go, mid + 1, lo)
        hi = np.where(active & ~go, mid, hi)
    return lo


def _broadcast_rows(tl: TimelineArrays, g: int) -> TimelineArrays:
    """Broadcast a single-row bank to ``g`` query rows (views, no copy)."""
    r = tl.n_rows
    if r == g:
        return tl
    if r != 1:
        raise ValueError(f"{g} query rows for {r} timeline rows")
    return TimelineArrays(
        np.broadcast_to(tl.edges, (g, tl.edges.shape[1])),
        np.broadcast_to(tl.powers, (g, tl.powers.shape[1])),
        np.broadcast_to(tl.idle_w, (g,)),
        np.broadcast_to(tl.n_segs, (g,)))


def cum_energy(tl: TimelineArrays) -> np.ndarray:
    """Per-row cumulative segment energy [R, S+1] (zero at the first edge)."""
    seg = tl.powers * np.diff(tl.edges, axis=1)
    return np.concatenate(
        [np.zeros((tl.n_rows, 1)), np.cumsum(seg, axis=1)], axis=1)


def timeline_integral(tl: TimelineArrays, t0: np.ndarray,
                      t1: np.ndarray) -> np.ndarray:
    """Exact per-row ∫P_i dt over [t0_i, t1_i] [G, M]; idle outside
    coverage.  ``tl`` has G rows, or 1 row broadcast against G."""
    t0 = np.asarray(t0, dtype=np.float64)
    t1 = np.asarray(t1, dtype=np.float64)
    g = t0.shape[0]
    cum = cum_energy(tl)            # on the R stored rows, then broadcast
    tl = _broadcast_rows(tl, g)
    e, p, idle, ns = tl
    if cum.shape[0] != g:
        cum = np.broadcast_to(cum, (g, cum.shape[1]))
    first = e[:, 0][:, None]
    last = e[:, -1][:, None]
    hi_idx = np.maximum(ns - 1, 0)[:, None]

    def eval_I(t):
        tc = np.clip(t, first, last)
        idx = np.clip(searchsorted_rows(e, tc, "right") - 1, 0, hi_idx)
        inner = (np.take_along_axis(cum, idx, axis=1)
                 + np.take_along_axis(p, idx, axis=1)
                 * (tc - np.take_along_axis(e, idx, axis=1)))
        before = np.minimum(t - first, 0.0) * idle[:, None]
        after = np.maximum(t - last, 0.0) * idle[:, None]
        return inner + before + after

    return eval_I(t1) - eval_I(t0)


def boxcar_means(tl: TimelineArrays, t0: np.ndarray,
                 t1: np.ndarray) -> np.ndarray:
    """Batched trailing-window means: ∫P dt / (t1 - t0) over [G, M]
    windows — the boxcar transient's raw reading."""
    t0 = np.asarray(t0, dtype=np.float64)
    t1 = np.asarray(t1, dtype=np.float64)
    dt = np.maximum(t1 - t0, 1e-12)
    return timeline_integral(tl, t0, t1) / dt


def estimation_means(tl: TimelineArrays, t0: np.ndarray, t1: np.ndarray,
                     model_gain: np.ndarray) -> np.ndarray:
    """Activity-proxy transient: the true period mean seen through a crude
    per-device activity model (``model_gain`` [G])."""
    return boxcar_means(tl, t0, t1) * np.asarray(model_gain)[:, None]


def log_filter(tl: TimelineArrays, ticks: np.ndarray,
               tau: np.ndarray) -> np.ndarray:
    """Batched first-order filter y' = (P - y)/tau for G devices.

    The scalar ``OnboardSensor._filtered_at`` walks the piecewise-constant
    segments in a per-device Python loop; here one scan advances a vector
    of G filter states per step.  With a shared timeline (single-row bank)
    the loop length is the number of timeline edges — independent of fleet
    size; with per-device rows the scan walks each row's own padded edge
    sequence, masking the zero-width padding steps so the state carries
    through unchanged.  Before the first real edge the state is exactly
    ``idle_w`` (the ``t_lo`` padding only ever covers idle), so readings
    are bitwise identical to the scalar filter for any padding choice.
    """
    g, _ = ticks.shape
    tau = np.asarray(tau, dtype=np.float64)
    t_lo = (min(float(np.min(ticks)), float(np.min(tl.t_start)))
            - 5.0 * float(np.max(tau)))
    t_hi = max(float(np.max(ticks)), float(np.max(tl.t_end))) + 1e-9
    r = tl.n_rows
    ext_e = np.concatenate([np.full((r, 1), t_lo), tl.edges,
                            np.full((r, 1), t_hi)], axis=1)
    ext_p = np.concatenate([tl.idle_w[:, None], tl.powers,
                            tl.idle_w[:, None]], axis=1)
    n_seg = ext_p.shape[1]
    dts = np.diff(ext_e, axis=1)

    y = np.empty((g, n_seg + 1))
    y[:, 0] = np.broadcast_to(tl.idle_w, (g,))
    for i in range(n_seg):
        dt = dts[:, i]
        sp = ext_p[:, i]
        step = sp + (y[:, i] - sp) * np.exp(-dt / tau)
        y[:, i + 1] = np.where(dt > 0, step, y[:, i])

    idx = np.clip(searchsorted_rows(ext_e, ticks, side="right") - 1,
                  0, n_seg - 1)
    y_at = np.take_along_axis(y, idx, axis=1)
    sp_at = np.take_along_axis(np.broadcast_to(ext_p, (g, n_seg)), idx,
                               axis=1)
    e_at = np.take_along_axis(np.broadcast_to(ext_e, (g, n_seg + 1)), idx,
                              axis=1)
    return sp_at + (y_at - sp_at) * np.exp(-(ticks - e_at) / tau[:, None])


def poll_counts(sched: ReadingSchedule, grid: PollGrid, a: np.ndarray,
                b: np.ndarray) -> Tuple[np.ndarray, np.ndarray,
                                        np.ndarray, np.ndarray]:
    """Closed-form poll counting over uniform grids: the core of
    ``SensorBank.integrate_polled``.

    Because the poll grid is uniform and the published readings are a
    step function over the tick grid, the number of poll instants falling
    inside each reading interval has a closed form — no [N, n_poll]
    reading matrix is ever materialised.  Returns

    * ``counts`` [N, M]  — poll instants covered by each reading slot
      within the selected index range,
    * ``slot_b`` [N]     — the reading slot current at the final selected
      poll instant (for the partial last step),
    * ``tail_dt`` [N]    — ``b - r(j1)``, the partial step the final poll
      instant integrates over,
    * ``nonempty`` [N]   — whether any poll instant landed in [a, b].

    The caller contracts ``period · Σ_k v_k · counts_k + v_{slot_b} ·
    tail_dt`` (zeroed where empty), which matches
    ``meter._integrate_readings`` on the equivalent polled series.
    """
    a = np.asarray(a, dtype=np.float64)
    b = np.asarray(b, dtype=np.float64)
    n = a.shape[0]
    period_s = grid.period_s
    # per-device poll ends reproduce each scalar sensor's finite grid
    m_i = np.floor((np.asarray(grid.t1, dtype=np.float64) - grid.t0)
                   / period_s).astype(np.int64)

    def q(idx):
        # true wall-clock query instant, same expression as poll()
        return grid.t0 + period_s * idx

    def r(idx):
        # reported (possibly re-synchronised) poll timestamp
        return (grid.t0 + period_s * idx) + grid.grid_offset

    # per-device selected index range [j0, j1] on the shared grid,
    # settling FP boundary cases against the actual grid values
    j0 = np.ceil((a - grid.grid_offset - grid.t0) / period_s).astype(np.int64)
    j1 = np.floor((b - grid.grid_offset - grid.t0) / period_s).astype(np.int64)
    for _ in range(2):
        j0 = np.where(r(j0 - 1) >= a, j0 - 1, j0)
        j0 = np.where(r(j0) < a, j0 + 1, j0)
        j1 = np.where(r(j1 + 1) <= b, j1 + 1, j1)
        j1 = np.where(r(j1) > b, j1 - 1, j1)
    j0 = np.maximum(j0, 0)
    j1 = np.minimum(j1, m_i - 1)

    ticks = sched.ticks
    m = ticks.shape[1]
    slot = np.arange(m)[None, :]
    # lo[k]: first poll index whose reading is slot k, i.e. smallest j
    # with q(j) >= tick_k (two FP settling passes, like query())
    lo = np.ceil((ticks - grid.t0) / period_s).astype(np.int64)
    for _ in range(2):
        lo = np.where(q(lo - 1) >= ticks, lo - 1, lo)
        lo = np.where(q(lo) < ticks, lo + 1, lo)
    hi = np.concatenate([lo[:, 1:] - 1, np.full((n, 1), _FAR)], axis=1)
    # query() clamps to [first, last]: the first reading extends back to
    # -inf, the last forward to +inf
    lo = np.where(slot == sched.first[:, None], np.int64(0), lo)
    hi = np.where(slot == sched.last[:, None], _FAR, hi)
    counts = (np.minimum(hi, (j1 - 1)[:, None])
              - np.maximum(lo, j0[:, None]) + 1)
    valid = (slot >= sched.first[:, None]) & (slot <= sched.last[:, None])
    counts = np.where(valid, np.maximum(counts, 0), 0)

    slot_b = query_slots(sched, q(j1.astype(np.float64))[:, None])[:, 0]
    tail_dt = b - r(j1.astype(np.float64))
    return counts, slot_b, tail_dt, j1 >= j0


def err_moments(e: np.ndarray) -> Tuple[int, float, float, float, float]:
    """One slab's error-moment reduction for the streaming fleet audit:
    ``(count, mean, M2, mean_abs, max_abs)``.  Slabs merge by Chan's
    parallel-Welford update (:class:`repro.core.fleet_engine.\
StreamingMoments`), so a chunked audit never reduces over all N errors
    at once."""
    e = np.asarray(e, dtype=np.float64)
    n = int(e.size)
    if n == 0:
        return 0, 0.0, 0.0, 0.0, 0.0
    mean = float(np.mean(e))
    m2 = float(np.sum((e - mean) ** 2))
    ae = np.abs(e)
    return n, mean, m2, float(np.mean(ae)), float(np.max(ae))


def query_slots(sched: ReadingSchedule, tq: np.ndarray) -> np.ndarray:
    """Reading slot current at wall-clock times ``tq`` [N, K]: the
    arithmetic index (same ``phase + T·k`` expression that built the
    grid), settled against the stored tick values and clamped to each
    device's valid range — identical to ``SensorBank.query``'s indexing.
    """
    T = sched.update_period_s[:, None]
    phase = sched.phase[:, None]
    m = sched.ticks.shape[1]
    j = np.floor((tq - phase) / T).astype(np.int64) - sched.k0[:, None]
    j = np.clip(j, 0, m - 1)
    # the arithmetic index can be off by one ulp at tick boundaries;
    # settle it against the actual stored tick values (two passes are
    # enough: the estimate is within ±1 of the true slot)
    for _ in range(2):
        tj = np.take_along_axis(sched.ticks, j, axis=1)
        j = np.where((tj > tq) & (j > 0), j - 1, j)
    for _ in range(2):
        jn = np.minimum(j + 1, m - 1)
        tn = np.take_along_axis(sched.ticks, jn, axis=1)
        j = np.where((tn <= tq) & (jn > j), jn, j)
    return np.clip(j, sched.first[:, None], sched.last[:, None])
