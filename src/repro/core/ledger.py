"""Per-run energy ledger — checkpoint-persistable energy accounting.

Each training/serving step appends one entry with both the naive sensor
integral and the good-practice-corrected estimate plus an uncertainty.
The ledger survives checkpoint/restart (fault tolerance must not lose
energy accounting; see DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
import json
from typing import List, Optional

import numpy as np


@dataclasses.dataclass
class LedgerEntry:
    step: int
    t0: float
    t1: float
    naive_j: float
    corrected_j: float
    sigma_j: float

    @property
    def duration_s(self) -> float:
        return self.t1 - self.t0


@dataclasses.dataclass
class EnergyLedger:
    device_id: str = "device0"
    entries: List[LedgerEntry] = dataclasses.field(default_factory=list)

    def append(self, step: int, t0: float, t1: float, naive_j: float,
               corrected_j: float, sigma_j: float = 0.0) -> None:
        self.entries.append(LedgerEntry(step, t0, t1, naive_j,
                                        corrected_j, sigma_j))

    @property
    def total_naive_j(self) -> float:
        return float(sum(e.naive_j for e in self.entries))

    @property
    def total_corrected_j(self) -> float:
        return float(sum(e.corrected_j for e in self.entries))

    @property
    def total_sigma_j(self) -> float:
        # per-step sigmas from one device share the same gain error =>
        # correlated; add linearly, not in quadrature
        return float(sum(e.sigma_j for e in self.entries))

    @property
    def total_duration_s(self) -> float:
        return float(sum(e.duration_s for e in self.entries))

    def mean_power_w(self) -> float:
        d = self.total_duration_s
        return self.total_corrected_j / d if d > 0 else 0.0

    # -- persistence -------------------------------------------------------
    def to_json(self) -> str:
        return json.dumps({
            "device_id": self.device_id,
            "entries": [dataclasses.asdict(e) for e in self.entries],
        })

    @classmethod
    def from_json(cls, s: str) -> "EnergyLedger":
        d = json.loads(s)
        led = cls(device_id=d["device_id"])
        led.entries = [LedgerEntry(**e) for e in d["entries"]]
        return led

    def summary(self) -> dict:
        return {
            "device_id": self.device_id,
            "steps": len(self.entries),
            "total_naive_j": self.total_naive_j,
            "total_corrected_j": self.total_corrected_j,
            "total_sigma_j": self.total_sigma_j,
            "mean_power_w": self.mean_power_w(),
            "naive_vs_corrected": (
                (self.total_naive_j - self.total_corrected_j)
                / self.total_corrected_j if self.total_corrected_j else 0.0),
        }
