"""Benchmark-load generators (the paper's §3.4, in timeline form).

The paper's load is a square wave: the high state is a data-dependent FMA
chain whose duration is linear in chain length and whose amplitude is set
by the fraction of SMs activated; the low state is a timed sleep.  Here the
same loads are expressed as :class:`ActivityTimeline` fragments.  The *live*
counterpart — actually executing the FMA chain as a Pallas TPU kernel and
fitting the duration/iterations line (Fig. 5) — lives in
``repro.kernels.fma_chain`` + ``benchmarks/load_linearity.py``.
"""
from __future__ import annotations

import numpy as np

from repro.core.ground_truth import ActivityTimeline, from_segments


def amplitude_for_fraction(fraction: float, idle_w: float = 60.0,
                           peak_w: float = 250.0) -> float:
    """Power drawn when ``fraction`` of the compute units run the FMA chain.

    Fig. 8 shows roughly equally-spaced plateaus for 20/40/60/80/100 % of
    SMs — i.e. near-linear — with idle further away (lower p-state).  We
    model the p-state gap with a small activation floor.
    """
    if fraction <= 0.0:
        return idle_w
    floor = 0.15 * (peak_w - idle_w)
    return idle_w + floor + (peak_w - idle_w - floor) * float(fraction)


def square_wave(period_s: float, n_cycles: int, p_high: float,
                p_low: float = 60.0, duty: float = 0.5, t0: float = 0.0,
                idle_w: float = 60.0,
                period_jitter_s: float = 0.0, seed: int = 0) -> ActivityTimeline:
    """High/low square wave; jitter models the imperfect kernel-length
    control that produced the paper's aliasing discovery (§4.3)."""
    rng = np.random.default_rng(seed)
    segs = []
    for _ in range(n_cycles):
        jit = rng.uniform(-period_jitter_s, period_jitter_s) if period_jitter_s else 0.0
        high = max(1e-4, period_s * duty + jit)
        low = max(1e-4, period_s * (1 - duty))
        segs.append((high, p_high))
        segs.append((low, p_low))
    return from_segments(segs, t0=t0, idle_w=idle_w)


def step(t_on: float, duration_s: float, p_high: float,
         p_low: float = 60.0, idle_w: float = 60.0,
         tail_s: float = 1.0) -> ActivityTimeline:
    """Single step for transient-response probing (paper uses 6 s)."""
    return from_segments(
        [(t_on, p_low), (duration_s, p_high), (tail_s, p_low)],
        t0=0.0, idle_w=idle_w)


def plateaus(levels_w: list[float], dwell_s: float = 4.0,
             idle_w: float = 60.0, gap_s: float = 1.0) -> ActivityTimeline:
    """Steady plateaus for steady-state gain/offset regression (Fig. 8)."""
    segs = []
    for w in levels_w:
        segs.append((dwell_s, w))
        segs.append((gap_s, idle_w))
    return from_segments(segs, idle_w=idle_w)


def workload_burst(duration_s: float, p_active: float,
                   idle_w: float = 60.0) -> ActivityTimeline:
    """One repetition of a real workload modelled as a constant-power
    burst (the paper's per-kernel execution window)."""
    return from_segments([(duration_s, p_active)], idle_w=idle_w)


def multi_phase_workload(phases: list[tuple[float, float]],
                         idle_w: float = 60.0) -> ActivityTimeline:
    """A workload with several internal phases (e.g. compute-bound matmul
    then memory-bound softmax) — (duration_s, watts) list."""
    return from_segments(phases, idle_w=idle_w)
