"""Benchmark-load generators (the paper's §3.4, in timeline form).

The paper's load is a square wave: the high state is a data-dependent FMA
chain whose duration is linear in chain length and whose amplitude is set
by the fraction of SMs activated; the low state is a timed sleep.  Here the
same loads are expressed as :class:`ActivityTimeline` fragments.  The *live*
counterpart — actually executing the FMA chain as a Pallas TPU kernel and
fitting the duration/iterations line (Fig. 5) — lives in
``repro.kernels.fma_chain`` + ``benchmarks/load_linearity.py``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.ground_truth import (ActivityTimeline, TimelineBank,
                                     from_segments)


def amplitude_for_fraction(fraction: float, idle_w: float = 60.0,
                           peak_w: float = 250.0) -> float:
    """Power drawn when ``fraction`` of the compute units run the FMA chain.

    Fig. 8 shows roughly equally-spaced plateaus for 20/40/60/80/100 % of
    SMs — i.e. near-linear — with idle further away (lower p-state).  We
    model the p-state gap with a small activation floor.
    """
    if fraction <= 0.0:
        return idle_w
    floor = 0.15 * (peak_w - idle_w)
    return idle_w + floor + (peak_w - idle_w - floor) * float(fraction)


def square_wave(period_s: float, n_cycles: int, p_high: float,
                p_low: float = 60.0, duty: float = 0.5, t0: float = 0.0,
                idle_w: float = 60.0,
                period_jitter_s: float = 0.0, seed: int = 0) -> ActivityTimeline:
    """High/low square wave; jitter models the imperfect kernel-length
    control that produced the paper's aliasing discovery (§4.3)."""
    rng = np.random.default_rng(seed)
    segs = []
    for _ in range(n_cycles):
        jit = rng.uniform(-period_jitter_s, period_jitter_s) if period_jitter_s else 0.0
        high = max(1e-4, period_s * duty + jit)
        low = max(1e-4, period_s * (1 - duty))
        segs.append((high, p_high))
        segs.append((low, p_low))
    return from_segments(segs, t0=t0, idle_w=idle_w)


def step(t_on: float, duration_s: float, p_high: float,
         p_low: float = 60.0, idle_w: float = 60.0,
         tail_s: float = 1.0) -> ActivityTimeline:
    """Single step for transient-response probing (paper uses 6 s)."""
    return from_segments(
        [(t_on, p_low), (duration_s, p_high), (tail_s, p_low)],
        t0=0.0, idle_w=idle_w)


def plateaus(levels_w: list[float], dwell_s: float = 4.0,
             idle_w: float = 60.0, gap_s: float = 1.0) -> ActivityTimeline:
    """Steady plateaus for steady-state gain/offset regression (Fig. 8)."""
    segs = []
    for w in levels_w:
        segs.append((dwell_s, w))
        segs.append((gap_s, idle_w))
    return from_segments(segs, idle_w=idle_w)


def workload_burst(duration_s: float, p_active: float,
                   idle_w: float = 60.0) -> ActivityTimeline:
    """One repetition of a real workload modelled as a constant-power
    burst (the paper's per-kernel execution window)."""
    return from_segments([(duration_s, p_active)], idle_w=idle_w)


def multi_phase_workload(phases: list[tuple[float, float]],
                         idle_w: float = 60.0) -> ActivityTimeline:
    """A workload with several internal phases (e.g. compute-bound matmul
    then memory-bound softmax) — (duration_s, watts) list."""
    return from_segments(phases, idle_w=idle_w)


# ---------------------------------------------------------------------------
# Scenario generators: per-device workload fragments for mixed fleets
# ---------------------------------------------------------------------------
# The paper's data-centre argument (§6) is about fleets running *different
# concurrent workloads*, each interacting differently with the part-time
# sample window.  Each generator below draws one device's repetition
# fragment from a seeded rng, so a 10k-device fleet gets 10k distinct
# timelines — the per-scenario error spread is then emergent from workload
# shape, not seed noise.

def training_step_timeline(seed: int = 0, idle_w: float = 60.0,
                           peak_w: float = 250.0) -> ActivityTimeline:
    """One training step: a compute-bound phase (matmul-heavy, near peak)
    followed by a communication/collective phase at lower draw, with
    per-device jitter in both duration and amplitude (stragglers, binning).
    """
    rng = np.random.default_rng(seed)
    compute = float(rng.uniform(0.100, 0.160))
    collective = float(rng.uniform(0.040, 0.080))
    p_hi = float(peak_w * rng.uniform(0.82, 0.95))
    p_lo = float(peak_w * rng.uniform(0.55, 0.70))
    return multi_phase_workload([(compute, p_hi), (collective, p_lo)],
                                idle_w=idle_w)


def inference_serving_timeline(seed: int = 0, window_s: float = 0.350,
                               rate_hz: float = 14.0,
                               idle_w: float = 60.0,
                               peak_w: float = 250.0,
                               max_bursts: int = 12) -> ActivityTimeline:
    """A serving window with bursty Poisson request arrivals: K ~
    Poisson(rate · window) requests land at uniform times, each a short
    high-power burst; overlapping bursts merge.  Exactly the part-time
    sensor's worst case — activity the 25 ms window may never see.

    The burst count is clipped at ``max_bursts`` (default 12) to bound
    the segment count per window.  The clip truncates the Poisson upper
    tail, so for heavy rates (``rate_hz · window_s`` approaching or
    exceeding ``max_bursts``) the *realised* arrival rate is biased low —
    raise ``max_bursts`` when sweeping rates instead of relying on the
    default (the truncation was previously a silent ``min(·, 12)``).
    """
    if max_bursts < 1:
        raise ValueError(f"max_bursts must be >= 1, got {max_bursts}")
    rng = np.random.default_rng(seed)
    k = min(int(rng.poisson(rate_hz * window_s)), max_bursts)
    p_hi = float(peak_w * rng.uniform(0.75, 0.92))
    if k == 0:
        return from_segments([(window_s, idle_w)], idle_w=idle_w)
    arrivals = np.sort(rng.uniform(0.0, window_s, size=k))
    lengths = np.maximum(rng.exponential(0.012, size=k), 0.002)
    segs: list[tuple[float, float]] = []
    cursor = 0.0
    busy_until = 0.0
    for a, d in zip(arrivals, lengths):
        end = min(float(a + d), window_s)
        if a > busy_until:                       # idle gap, then the burst
            segs.append((float(a) - cursor, idle_w))
            cursor = float(a)
        end = max(end, busy_until)
        if end > cursor:
            segs.append((end - cursor, p_hi))
            cursor = end
        busy_until = max(busy_until, end)
    if cursor < window_s:
        segs.append((window_s - cursor, idle_w))
    return from_segments(segs, idle_w=idle_w)


def idle_maintenance_timeline(seed: int = 0, window_s: float = 0.450,
                              idle_w: float = 60.0,
                              peak_w: float = 250.0) -> ActivityTimeline:
    """A drained / maintenance device: near-idle with one short health
    check blip at a random position (the fleet's 'dark' energy that naive
    accounting silently extrapolates from busy neighbours)."""
    rng = np.random.default_rng(seed)
    blip = float(rng.uniform(0.015, 0.035))
    at = float(rng.uniform(0.0, window_s - blip))
    p_blip = float(idle_w + (peak_w - idle_w) * rng.uniform(0.2, 0.4))
    p_floor = float(idle_w * rng.uniform(1.0, 1.15))
    return from_segments([(at, p_floor), (blip, p_blip),
                          (window_s - at - blip, p_floor)], idle_w=idle_w)


def diurnal_cycle_timeline(seed: int = 0, window_s: float = 0.300,
                           idle_w: float = 60.0, peak_w: float = 250.0,
                           n_steps: int = 6) -> ActivityTimeline:
    """A slice of a diurnal utilisation cycle: the device's load follows a
    sinusoidal day curve sampled at a random phase (hour of day), stepped
    into plateaus — the slow-varying counterpart to the bursty scenarios.
    """
    rng = np.random.default_rng(seed)
    phase = float(rng.uniform(0.0, 2.0 * np.pi))
    depth = float(rng.uniform(0.5, 0.9))
    hours = phase + np.linspace(0.0, np.pi / 3.0, n_steps)   # ~4 h slice
    util = 0.5 * (1.0 + np.sin(hours)) * depth
    dwell = window_s / n_steps
    segs = [(dwell, amplitude_for_fraction(float(u), idle_w, peak_w))
            for u in util]
    return from_segments(segs, idle_w=idle_w)


# -- adversarial fleet dynamics (ROADMAP item (b)) --------------------------
# DVFS ramps, thermal-throttle sag, power-cap clipping and mid-window node
# failures bend the power trace *under* the sampler: the part-time window
# sees a level the device only held for part of the window.  Each generator
# keeps the square-wave segment vocabulary so the whole correction pipeline
# applies unchanged.

def dvfs_ramp_timeline(seed: int = 0, window_s: float = 0.360,
                       idle_w: float = 60.0, peak_w: float = 250.0,
                       n_steps: int = 8) -> ActivityTimeline:
    """A DVFS frequency ramp: the governor walks the clock through
    ``n_steps`` p-states, so power climbs (or descends) a curved staircase
    across the window — no plateau lasts long enough for a part-time
    sampler to average honestly."""
    rng = np.random.default_rng(seed)
    lo_f = rng.uniform(0.30, 0.45)
    hi_f = rng.uniform(0.85, 0.97)
    gamma = rng.uniform(0.6, 1.6)              # curvature of the ramp
    up = rng.uniform(0.0, 1.0) < 0.5
    frac = np.linspace(0.0, 1.0, n_steps)
    p = peak_w * (lo_f + (hi_f - lo_f) * frac ** gamma)
    if not up:
        p = p[::-1]
    dwell = window_s / n_steps
    return from_segments([(dwell, float(w)) for w in p], idle_w=idle_w)


def thermal_throttle_timeline(seed: int = 0, window_s: float = 0.420,
                              idle_w: float = 60.0, peak_w: float = 250.0,
                              n_steps: int = 7) -> ActivityTimeline:
    """Thermal-throttle sag: the device starts near peak and decays
    exponentially toward a sustained throttled level as the hotspot
    saturates — a slow transient the sampler's duty cycle aliases."""
    rng = np.random.default_rng(seed)
    p0 = rng.uniform(0.88, 0.97)
    p_inf = rng.uniform(0.60, 0.75)
    tau = rng.uniform(0.25, 0.60)              # decay constant, in windows
    mid = (np.arange(n_steps) + 0.5) * (window_s / n_steps)
    sag = np.exp(-mid / (window_s * tau))
    p = peak_w * (p_inf + (p0 - p_inf) * sag)
    dwell = window_s / n_steps
    return from_segments([(dwell, float(w)) for w in p], idle_w=idle_w)


def power_cap_timeline(seed: int = 0, window_s: float = 0.400,
                       idle_w: float = 60.0, peak_w: float = 250.0,
                       n_steps: int = 8) -> ActivityTimeline:
    """Power-cap clipping: free-running demand fluctuates step to step but
    the board limit clips every excursion above the cap, flattening the
    peaks a naive reading would extrapolate from."""
    rng = np.random.default_rng(seed)
    demand_f = rng.uniform(0.55, 1.05, size=n_steps)
    cap_f = rng.uniform(0.70, 0.85)
    demand = idle_w + (peak_w - idle_w) * demand_f
    p = np.minimum(demand, peak_w * cap_f)
    dwell = window_s / n_steps
    return from_segments([(dwell, float(w)) for w in p], idle_w=idle_w)


def node_failure_timeline(seed: int = 0, window_s: float = 0.400,
                          idle_w: float = 60.0,
                          peak_w: float = 250.0) -> ActivityTimeline:
    """Node failure mid-window: full load until a random failure instant,
    then a PSU/fan trickle — any sample taken before the death keeps
    billing the device at load unless coverage is reported honestly."""
    rng = np.random.default_rng(seed)
    p_run = peak_w * rng.uniform(0.78, 0.94)
    at = window_s * rng.uniform(0.20, 0.85)
    p_dead = idle_w * rng.uniform(0.02, 0.10)
    return from_segments([(at, float(p_run)),
                          (window_s - at, float(p_dead))], idle_w=idle_w)


SCENARIOS = {
    "training": training_step_timeline,
    "inference": inference_serving_timeline,
    "idle": idle_maintenance_timeline,
    "diurnal": diurnal_cycle_timeline,
    "dvfs": dvfs_ramp_timeline,
    "throttle": thermal_throttle_timeline,
    "powercap": power_cap_timeline,
    "node_failure": node_failure_timeline,
}

DEFAULT_MIX = {"training": 0.40, "inference": 0.30,
               "idle": 0.15, "diurnal": 0.15}

# an all-adversarial fleet for resilience drills: every device is mid-ramp,
# throttling, capped, or dying — the stress complement of DEFAULT_MIX
ADVERSARIAL_MIX = {"dvfs": 0.30, "throttle": 0.25,
                   "powercap": 0.25, "node_failure": 0.20}


def scenario_timeline(kind: str, seed: int = 0, idle_w: float = 60.0,
                      peak_w: float = 250.0) -> ActivityTimeline:
    """One device's repetition fragment for a named scenario."""
    try:
        builder = SCENARIOS[kind]
    except KeyError:
        raise KeyError(f"unknown scenario '{kind}'; "
                       f"available: {sorted(SCENARIOS)}") from None
    return builder(seed=seed, idle_w=idle_w, peak_w=peak_w)


def _mix_labels(n: int, mix: dict[str, float] | None, seed: int) -> np.ndarray:
    """The per-device scenario assignment shared by the object and array
    paths: largest-remainder apportioning of ``mix`` over ``n`` devices,
    shuffled by ``default_rng(seed).permutation`` so profiles and
    scenarios decorrelate.  Returns an ``[n]`` array of kind labels."""
    if n < 1:
        raise ValueError("need at least one device")
    mix = dict(DEFAULT_MIX if mix is None else mix)
    for kind in mix:
        if kind not in SCENARIOS:
            raise KeyError(f"unknown scenario '{kind}'; "
                           f"available: {sorted(SCENARIOS)}")
    total = sum(mix.values())
    if total <= 0:
        raise ValueError("scenario mix fractions must sum to > 0")
    kinds = sorted(mix)
    exact = np.array([mix[k] / total * n for k in kinds])
    counts = np.floor(exact).astype(int)
    rema = exact - counts
    for i in np.argsort(-rema)[: n - int(counts.sum())]:
        counts[i] += 1
    labels = np.repeat(np.array(kinds), counts)
    rng = np.random.default_rng(seed)
    return labels[rng.permutation(n)]


def mixed_fleet_workloads(n: int, mix: dict[str, float] | None = None,
                          seed: int = 0, idle_w: float = 60.0,
                          peak_w: float = 250.0, as_bank: bool = False):
    """N per-device workloads drawn from a scenario mix — every device its
    own timeline, labelled for per-scenario error breakdowns.

    ``mix`` maps scenario name → fraction (normalised); counts are
    apportioned deterministically (largest remainder) and the assignment
    is shuffled so profiles and scenarios decorrelate.  Returns a list of
    :class:`repro.core.meter.Workload` ready for ``fleet_audit`` /
    ``measure_*_batch`` — or, with ``as_bank=True``, a bank-native
    :class:`repro.core.meter.WorkloadSet` built by
    :func:`mixed_fleet_bank` without materialising any per-device Python
    objects (same timelines bitwise, ~50× faster at fleet scale).
    """
    from repro.core.meter import Workload, WorkloadSet

    if as_bank:
        bank, labels = mixed_fleet_bank(n, mix=mix, seed=seed,
                                        idle_w=idle_w, peak_w=peak_w)
        return WorkloadSet(bank=bank, scenarios=labels)
    labels = _mix_labels(n, mix, seed)
    return [
        Workload(f"{kind}[{i}]",
                 scenario_timeline(kind, seed=seed + 1 + i,
                                   idle_w=idle_w, peak_w=peak_w),
                 scenario=str(kind))
        for i, kind in enumerate(labels)
    ]


# ---------------------------------------------------------------------------
# Array-native scenario synthesis: batched samplers over [N] seed lanes
# ---------------------------------------------------------------------------
# Each scalar generator above has a vectorized counterpart that draws all
# N devices' parameters from `engine_backend.vecrng.VecStreams` — N
# independent `default_rng(seed_i)`-equivalent streams advanced in
# lock-step — and writes padded [N, S] edge/power arrays straight into a
# `TimelineBank`.  Because the streams are bitwise the scalar generators'
# streams and every float op is replicated in the scalar order, row i of
# `scenario_bank(kind, seeds)` is *bitwise* `scenario_timeline(kind,
# seed=seeds[i])` (pinned by tests/test_load_bank.py); the scalar
# generators stay the per-row reference semantics.

def _cum_edges(durs: np.ndarray, n_segs: np.ndarray) -> np.ndarray:
    """`from_segments`' sequential edge accumulation, batched: edge j+1 =
    edge j + dur j (``np.add.accumulate`` folds left like the scalar
    loop, so the float rounding matches bitwise)."""
    n, s = durs.shape
    edges = np.empty((n, s + 1))
    edges[:, 0] = 0.0
    np.add.accumulate(durs, axis=1, out=edges[:, 1:])
    return edges


def training_step_bank(seeds, idle_w: float = 60.0,
                       peak_w: float = 250.0) -> TimelineBank:
    """Vectorized :func:`training_step_timeline`: row i is bitwise the
    scalar generator at ``seed=seeds[i]``."""
    from repro.core.engine_backend.vecrng import VecStreams

    streams = VecStreams(np.asarray(seeds))
    compute = streams.uniform(0.100, 0.160)
    collective = streams.uniform(0.040, 0.080)
    p_hi = peak_w * streams.uniform(0.82, 0.95)
    p_lo = peak_w * streams.uniform(0.55, 0.70)
    n = streams.n_lanes
    edges = _cum_edges(np.stack([compute, collective], axis=1),
                       np.full(n, 2))
    powers = np.stack([p_hi, p_lo], axis=1)
    return TimelineBank(edges, powers, np.full(n, idle_w),
                        np.full(n, 2, dtype=np.int64))


def inference_serving_bank(seeds, window_s: float = 0.350,
                           rate_hz: float = 14.0, idle_w: float = 60.0,
                           peak_w: float = 250.0,
                           max_bursts: int = 12) -> TimelineBank:
    """Vectorized :func:`inference_serving_timeline` (burst merging and
    all): row i is bitwise the scalar generator at ``seed=seeds[i]``."""
    from repro.core.engine_backend.vecrng import VecStreams

    if max_bursts < 1:
        raise ValueError(f"max_bursts must be >= 1, got {max_bursts}")
    streams = VecStreams(np.asarray(seeds))
    n = streams.n_lanes
    k = np.minimum(streams.poisson(rate_hz * window_s), max_bursts)
    p_hi = peak_w * streams.uniform(0.75, 0.92)
    arrivals = streams.uniform_block(0.0, window_s, k)
    kmax = arrivals.shape[1]
    arrivals[np.arange(kmax)[None, :] >= k[:, None]] = np.inf
    arrivals = np.sort(arrivals, axis=1)       # sorted prefix == np.sort
    lengths = np.maximum(streams.exponential_block(0.012, k), 0.002)

    # replay the scalar merge loop with vector state over devices: each
    # arrival may emit an idle-gap segment and extend/emit a burst
    # segment; zero-width non-emissions are compacted out below so the
    # segment list matches the scalar append-by-append
    dur = np.zeros((n, 2 * kmax + 1))
    pw = np.zeros((n, 2 * kmax + 1))
    emit = np.zeros((n, 2 * kmax + 1), dtype=bool)
    cursor = np.zeros(n)
    busy_until = np.zeros(n)
    for j in range(kmax):
        live = j < k
        a = np.where(live, arrivals[:, j], 0.0)
        d = np.where(live, lengths[:, j], 0.0)
        end = np.minimum(a + d, window_s)
        gap = live & (a > busy_until)
        dur[:, 2 * j] = np.where(gap, a - cursor, 0.0)
        pw[:, 2 * j] = idle_w
        emit[:, 2 * j] = gap
        cursor = np.where(gap, a, cursor)
        end = np.maximum(end, busy_until)
        burst = live & (end > cursor)
        dur[:, 2 * j + 1] = np.where(burst, end - cursor, 0.0)
        pw[:, 2 * j + 1] = np.where(burst, p_hi, idle_w)
        emit[:, 2 * j + 1] = burst
        cursor = np.where(burst, end, cursor)
        busy_until = np.where(live, np.maximum(busy_until, end), busy_until)
    tail = cursor < window_s
    dur[:, 2 * kmax] = np.where(tail, window_s - cursor, 0.0)
    pw[:, 2 * kmax] = idle_w
    emit[:, 2 * kmax] = tail
    # k == 0 lanes: the scalar path emits exactly [(window_s, idle_w)]
    zero = k == 0
    if np.any(zero):
        emit[zero] = False
        emit[zero, 0] = True
        dur[zero, 0] = window_s
        pw[zero, 0] = idle_w

    # compact emitted segments to each row's prefix
    n_segs = emit.sum(axis=1).astype(np.int64)
    smax = int(n_segs.max())
    rows = np.broadcast_to(np.arange(n)[:, None], emit.shape)
    slots = np.cumsum(emit, axis=1) - 1
    out_dur = np.zeros((n, smax))
    out_pw = np.full((n, smax), idle_w)
    out_dur[rows[emit], slots[emit]] = dur[emit]
    out_pw[rows[emit], slots[emit]] = pw[emit]
    return TimelineBank(_cum_edges(out_dur, n_segs), out_pw,
                        np.full(n, idle_w), n_segs)


def idle_maintenance_bank(seeds, window_s: float = 0.450,
                          idle_w: float = 60.0,
                          peak_w: float = 250.0) -> TimelineBank:
    """Vectorized :func:`idle_maintenance_timeline`: row i is bitwise the
    scalar generator at ``seed=seeds[i]``."""
    from repro.core.engine_backend.vecrng import VecStreams

    streams = VecStreams(np.asarray(seeds))
    n = streams.n_lanes
    blip = streams.uniform(0.015, 0.035)
    at = streams.uniform(0.0, window_s - blip)
    p_blip = idle_w + (peak_w - idle_w) * streams.uniform(0.2, 0.4)
    p_floor = idle_w * streams.uniform(1.0, 1.15)
    durs = np.stack([at, blip, (window_s - at) - blip], axis=1)
    powers = np.stack([p_floor, p_blip, p_floor], axis=1)
    return TimelineBank(_cum_edges(durs, np.full(n, 3)), powers,
                        np.full(n, idle_w), np.full(n, 3, dtype=np.int64))


def diurnal_cycle_bank(seeds, window_s: float = 0.300,
                       idle_w: float = 60.0, peak_w: float = 250.0,
                       n_steps: int = 6) -> TimelineBank:
    """Vectorized :func:`diurnal_cycle_timeline`: row i is bitwise the
    scalar generator at ``seed=seeds[i]``."""
    from repro.core.engine_backend.vecrng import VecStreams

    streams = VecStreams(np.asarray(seeds))
    n = streams.n_lanes
    phase = streams.uniform(0.0, 2.0 * np.pi)
    depth = streams.uniform(0.5, 0.9)
    hours = phase[:, None] + np.linspace(0.0, np.pi / 3.0, n_steps)[None, :]
    util = 0.5 * (1.0 + np.sin(hours)) * depth[:, None]
    floor = 0.15 * (peak_w - idle_w)
    amp = idle_w + floor + (peak_w - idle_w - floor) * util
    amp = np.where(util <= 0.0, idle_w, amp)
    dwell = window_s / n_steps
    durs = np.full((n, n_steps), dwell)
    return TimelineBank(_cum_edges(durs, np.full(n, n_steps)), amp,
                        np.full(n, idle_w),
                        np.full(n, n_steps, dtype=np.int64))


def dvfs_ramp_bank(seeds, window_s: float = 0.360, idle_w: float = 60.0,
                   peak_w: float = 250.0, n_steps: int = 8) -> TimelineBank:
    """Vectorized :func:`dvfs_ramp_timeline`: row i is bitwise the scalar
    generator at ``seed=seeds[i]``."""
    from repro.core.engine_backend.vecrng import VecStreams

    streams = VecStreams(np.asarray(seeds))
    n = streams.n_lanes
    lo_f = streams.uniform(0.30, 0.45)
    hi_f = streams.uniform(0.85, 0.97)
    gamma = streams.uniform(0.6, 1.6)
    up = streams.uniform(0.0, 1.0) < 0.5
    frac = np.linspace(0.0, 1.0, n_steps)
    p = peak_w * (lo_f[:, None]
                  + (hi_f - lo_f)[:, None] * frac[None, :] ** gamma[:, None])
    p = np.where(up[:, None], p, p[:, ::-1])
    durs = np.full((n, n_steps), window_s / n_steps)
    return TimelineBank(_cum_edges(durs, np.full(n, n_steps)), p,
                        np.full(n, idle_w),
                        np.full(n, n_steps, dtype=np.int64))


def thermal_throttle_bank(seeds, window_s: float = 0.420,
                          idle_w: float = 60.0, peak_w: float = 250.0,
                          n_steps: int = 7) -> TimelineBank:
    """Vectorized :func:`thermal_throttle_timeline`: row i is bitwise the
    scalar generator at ``seed=seeds[i]``."""
    from repro.core.engine_backend.vecrng import VecStreams

    streams = VecStreams(np.asarray(seeds))
    n = streams.n_lanes
    p0 = streams.uniform(0.88, 0.97)
    p_inf = streams.uniform(0.60, 0.75)
    tau = streams.uniform(0.25, 0.60)
    mid = (np.arange(n_steps) + 0.5) * (window_s / n_steps)
    sag = np.exp(-mid[None, :] / (window_s * tau)[:, None])
    p = peak_w * (p_inf[:, None] + (p0 - p_inf)[:, None] * sag)
    durs = np.full((n, n_steps), window_s / n_steps)
    return TimelineBank(_cum_edges(durs, np.full(n, n_steps)), p,
                        np.full(n, idle_w),
                        np.full(n, n_steps, dtype=np.int64))


def power_cap_bank(seeds, window_s: float = 0.400, idle_w: float = 60.0,
                   peak_w: float = 250.0, n_steps: int = 8) -> TimelineBank:
    """Vectorized :func:`power_cap_timeline`: row i is bitwise the scalar
    generator at ``seed=seeds[i]``."""
    from repro.core.engine_backend.vecrng import VecStreams

    streams = VecStreams(np.asarray(seeds))
    n = streams.n_lanes
    demand_f = streams.uniform_block(
        0.55, 1.05, np.full(n, n_steps, dtype=np.int64))
    cap_f = streams.uniform(0.70, 0.85)
    demand = idle_w + (peak_w - idle_w) * demand_f
    p = np.minimum(demand, (peak_w * cap_f)[:, None])
    durs = np.full((n, n_steps), window_s / n_steps)
    return TimelineBank(_cum_edges(durs, np.full(n, n_steps)), p,
                        np.full(n, idle_w),
                        np.full(n, n_steps, dtype=np.int64))


def node_failure_bank(seeds, window_s: float = 0.400, idle_w: float = 60.0,
                      peak_w: float = 250.0) -> TimelineBank:
    """Vectorized :func:`node_failure_timeline`: row i is bitwise the
    scalar generator at ``seed=seeds[i]``."""
    from repro.core.engine_backend.vecrng import VecStreams

    streams = VecStreams(np.asarray(seeds))
    n = streams.n_lanes
    p_run = peak_w * streams.uniform(0.78, 0.94)
    at = window_s * streams.uniform(0.20, 0.85)
    p_dead = idle_w * streams.uniform(0.02, 0.10)
    durs = np.stack([at, window_s - at], axis=1)
    powers = np.stack([p_run, p_dead], axis=1)
    return TimelineBank(_cum_edges(durs, np.full(n, 2)), powers,
                        np.full(n, idle_w), np.full(n, 2, dtype=np.int64))


SCENARIO_BANKS = {
    "training": training_step_bank,
    "inference": inference_serving_bank,
    "idle": idle_maintenance_bank,
    "diurnal": diurnal_cycle_bank,
    "dvfs": dvfs_ramp_bank,
    "throttle": thermal_throttle_bank,
    "powercap": power_cap_bank,
    "node_failure": node_failure_bank,
}


def scenario_bank(kind: str, seeds, idle_w: float = 60.0,
                  peak_w: float = 250.0) -> TimelineBank:
    """Batched :func:`scenario_timeline`: row i is bitwise
    ``scenario_timeline(kind, seed=seeds[i])``."""
    try:
        builder = SCENARIO_BANKS[kind]
    except KeyError:
        raise KeyError(f"unknown scenario '{kind}'; "
                       f"available: {sorted(SCENARIO_BANKS)}") from None
    return builder(seeds, idle_w=idle_w, peak_w=peak_w)


def mixed_fleet_bank(n: int, mix: dict[str, float] | None = None,
                     seed: int = 0, idle_w: float = 60.0,
                     peak_w: float = 250.0,
                     lo: int = 0, hi: int | None = None
                     ) -> tuple[TimelineBank, np.ndarray]:
    """Array-native :func:`mixed_fleet_workloads`: the same mixed fleet —
    same labels, same per-device timelines bitwise — synthesised as one
    padded :class:`TimelineBank` with no per-device Python objects.

    Returns ``(bank, labels)``.  ``lo``/``hi`` select a device slab
    (rows ``lo .. hi-1`` of the full fleet, identical to slicing the
    full bank) for streaming million-device synthesis with bounded
    memory — see :class:`FleetScenarioSpec` and ``docs/scaling.md``.
    """
    labels = _mix_labels(n, mix, seed)
    hi = n if hi is None else hi
    if not (0 <= lo < hi <= n):
        raise ValueError(f"bad slab [{lo}, {hi}) for {n} devices")
    labels = labels[lo:hi]
    dev = np.arange(lo, hi)
    banks = {}
    for kind in np.unique(labels):
        rows = np.flatnonzero(labels == kind)
        banks[kind] = (rows, SCENARIO_BANKS[str(kind)](
            seed + 1 + dev[rows], idle_w=idle_w, peak_w=peak_w))
    m = hi - lo
    smax = max(b.powers.shape[1] for _, b in banks.values())
    edges = np.zeros((m, smax + 1))
    powers = np.empty((m, smax))
    idle = np.empty(m)
    n_segs = np.empty(m, dtype=np.int64)
    for rows, b in banks.values():
        s = b.powers.shape[1]
        edges[rows, :s + 1] = b.edges
        edges[rows, s + 1:] = b.edges[:, -1:]
        powers[rows, :s] = b.powers
        powers[rows, s:] = b.idle_w[:, None]
        idle[rows] = b.idle_w
        n_segs[rows] = b.n_segs
    return TimelineBank(edges, powers, idle, n_segs), labels


@dataclasses.dataclass(frozen=True)
class FleetScenarioSpec:
    """A mixed fleet described by recipe instead of materialised arrays.

    ``fleet_audit(workload=spec, chunk_devices=...)`` synthesises each
    device slab on demand (`bank(lo, hi)`), so a million-device audit
    never holds more than one slab's timelines — workload generation
    streams along with the audit.  Slabs are exact row-ranges of the
    full fleet: auditing in any chunking yields bitwise the same
    per-device results.
    """

    n: int
    mix: Optional[dict] = None
    seed: int = 0
    idle_w: float = 60.0
    peak_w: float = 250.0

    def __post_init__(self):
        if self.n < 1:
            raise ValueError("need at least one device")
        _mix_labels(1, self.mix, self.seed)     # validate the mix up front

    def bank(self, lo: int = 0, hi: Optional[int] = None
             ) -> tuple[TimelineBank, np.ndarray]:
        return mixed_fleet_bank(self.n, mix=self.mix, seed=self.seed,
                                idle_w=self.idle_w, peak_w=self.peak_w,
                                lo=lo, hi=hi)

    def workload_set(self, lo: int = 0, hi: Optional[int] = None):
        """The slab as a bank-native :class:`repro.core.meter.WorkloadSet`."""
        from repro.core.meter import WorkloadSet
        bank, labels = self.bank(lo, hi)
        return WorkloadSet(bank=bank, scenarios=labels)

    def iter_workload_sets(self, slabs, prefetch: bool = False):
        """Yield ``workload_set(lo, hi)`` for each ``(lo, hi)`` in
        ``slabs``, optionally double-buffered.

        With ``prefetch=True`` slab *k+1* synthesises on a background
        thread while the consumer (the audit loop) works on slab *k* —
        sound because slabs are exact row-ranges with their own derived
        RNG substreams (vecrng seeds are per-device), so synthesis order
        and thread cannot change a single bit of any slab.  The consumed
        sequence is identical either way; ``prefetch=False`` is the
        plain sequential generator.
        """
        slabs = list(slabs)
        if not prefetch or len(slabs) <= 1:
            for lo, hi in slabs:
                yield self.workload_set(lo, hi)
            return
        from concurrent.futures import ThreadPoolExecutor
        with ThreadPoolExecutor(max_workers=1) as pool:
            fut = pool.submit(self.workload_set, *slabs[0])
            for nxt in slabs[1:]:
                cur = fut.result()
                fut = pool.submit(self.workload_set, *nxt)
                yield cur
            yield fut.result()
