"""Benchmark-load generators (the paper's §3.4, in timeline form).

The paper's load is a square wave: the high state is a data-dependent FMA
chain whose duration is linear in chain length and whose amplitude is set
by the fraction of SMs activated; the low state is a timed sleep.  Here the
same loads are expressed as :class:`ActivityTimeline` fragments.  The *live*
counterpart — actually executing the FMA chain as a Pallas TPU kernel and
fitting the duration/iterations line (Fig. 5) — lives in
``repro.kernels.fma_chain`` + ``benchmarks/load_linearity.py``.
"""
from __future__ import annotations

import numpy as np

from repro.core.ground_truth import ActivityTimeline, from_segments


def amplitude_for_fraction(fraction: float, idle_w: float = 60.0,
                           peak_w: float = 250.0) -> float:
    """Power drawn when ``fraction`` of the compute units run the FMA chain.

    Fig. 8 shows roughly equally-spaced plateaus for 20/40/60/80/100 % of
    SMs — i.e. near-linear — with idle further away (lower p-state).  We
    model the p-state gap with a small activation floor.
    """
    if fraction <= 0.0:
        return idle_w
    floor = 0.15 * (peak_w - idle_w)
    return idle_w + floor + (peak_w - idle_w - floor) * float(fraction)


def square_wave(period_s: float, n_cycles: int, p_high: float,
                p_low: float = 60.0, duty: float = 0.5, t0: float = 0.0,
                idle_w: float = 60.0,
                period_jitter_s: float = 0.0, seed: int = 0) -> ActivityTimeline:
    """High/low square wave; jitter models the imperfect kernel-length
    control that produced the paper's aliasing discovery (§4.3)."""
    rng = np.random.default_rng(seed)
    segs = []
    for _ in range(n_cycles):
        jit = rng.uniform(-period_jitter_s, period_jitter_s) if period_jitter_s else 0.0
        high = max(1e-4, period_s * duty + jit)
        low = max(1e-4, period_s * (1 - duty))
        segs.append((high, p_high))
        segs.append((low, p_low))
    return from_segments(segs, t0=t0, idle_w=idle_w)


def step(t_on: float, duration_s: float, p_high: float,
         p_low: float = 60.0, idle_w: float = 60.0,
         tail_s: float = 1.0) -> ActivityTimeline:
    """Single step for transient-response probing (paper uses 6 s)."""
    return from_segments(
        [(t_on, p_low), (duration_s, p_high), (tail_s, p_low)],
        t0=0.0, idle_w=idle_w)


def plateaus(levels_w: list[float], dwell_s: float = 4.0,
             idle_w: float = 60.0, gap_s: float = 1.0) -> ActivityTimeline:
    """Steady plateaus for steady-state gain/offset regression (Fig. 8)."""
    segs = []
    for w in levels_w:
        segs.append((dwell_s, w))
        segs.append((gap_s, idle_w))
    return from_segments(segs, idle_w=idle_w)


def workload_burst(duration_s: float, p_active: float,
                   idle_w: float = 60.0) -> ActivityTimeline:
    """One repetition of a real workload modelled as a constant-power
    burst (the paper's per-kernel execution window)."""
    return from_segments([(duration_s, p_active)], idle_w=idle_w)


def multi_phase_workload(phases: list[tuple[float, float]],
                         idle_w: float = 60.0) -> ActivityTimeline:
    """A workload with several internal phases (e.g. compute-bound matmul
    then memory-bound softmax) — (duration_s, watts) list."""
    return from_segments(phases, idle_w=idle_w)


# ---------------------------------------------------------------------------
# Scenario generators: per-device workload fragments for mixed fleets
# ---------------------------------------------------------------------------
# The paper's data-centre argument (§6) is about fleets running *different
# concurrent workloads*, each interacting differently with the part-time
# sample window.  Each generator below draws one device's repetition
# fragment from a seeded rng, so a 10k-device fleet gets 10k distinct
# timelines — the per-scenario error spread is then emergent from workload
# shape, not seed noise.

def training_step_timeline(seed: int = 0, idle_w: float = 60.0,
                           peak_w: float = 250.0) -> ActivityTimeline:
    """One training step: a compute-bound phase (matmul-heavy, near peak)
    followed by a communication/collective phase at lower draw, with
    per-device jitter in both duration and amplitude (stragglers, binning).
    """
    rng = np.random.default_rng(seed)
    compute = float(rng.uniform(0.100, 0.160))
    collective = float(rng.uniform(0.040, 0.080))
    p_hi = float(peak_w * rng.uniform(0.82, 0.95))
    p_lo = float(peak_w * rng.uniform(0.55, 0.70))
    return multi_phase_workload([(compute, p_hi), (collective, p_lo)],
                                idle_w=idle_w)


def inference_serving_timeline(seed: int = 0, window_s: float = 0.350,
                               rate_hz: float = 14.0,
                               idle_w: float = 60.0,
                               peak_w: float = 250.0) -> ActivityTimeline:
    """A serving window with bursty Poisson request arrivals: K ~
    Poisson(rate · window) requests land at uniform times, each a short
    high-power burst; overlapping bursts merge.  Exactly the part-time
    sensor's worst case — activity the 25 ms window may never see."""
    rng = np.random.default_rng(seed)
    k = min(int(rng.poisson(rate_hz * window_s)), 12)
    p_hi = float(peak_w * rng.uniform(0.75, 0.92))
    if k == 0:
        return from_segments([(window_s, idle_w)], idle_w=idle_w)
    arrivals = np.sort(rng.uniform(0.0, window_s, size=k))
    lengths = np.maximum(rng.exponential(0.012, size=k), 0.002)
    segs: list[tuple[float, float]] = []
    cursor = 0.0
    busy_until = 0.0
    for a, d in zip(arrivals, lengths):
        end = min(float(a + d), window_s)
        if a > busy_until:                       # idle gap, then the burst
            segs.append((float(a) - cursor, idle_w))
            cursor = float(a)
        end = max(end, busy_until)
        if end > cursor:
            segs.append((end - cursor, p_hi))
            cursor = end
        busy_until = max(busy_until, end)
    if cursor < window_s:
        segs.append((window_s - cursor, idle_w))
    return from_segments(segs, idle_w=idle_w)


def idle_maintenance_timeline(seed: int = 0, window_s: float = 0.450,
                              idle_w: float = 60.0,
                              peak_w: float = 250.0) -> ActivityTimeline:
    """A drained / maintenance device: near-idle with one short health
    check blip at a random position (the fleet's 'dark' energy that naive
    accounting silently extrapolates from busy neighbours)."""
    rng = np.random.default_rng(seed)
    blip = float(rng.uniform(0.015, 0.035))
    at = float(rng.uniform(0.0, window_s - blip))
    p_blip = float(idle_w + (peak_w - idle_w) * rng.uniform(0.2, 0.4))
    p_floor = float(idle_w * rng.uniform(1.0, 1.15))
    return from_segments([(at, p_floor), (blip, p_blip),
                          (window_s - at - blip, p_floor)], idle_w=idle_w)


def diurnal_cycle_timeline(seed: int = 0, window_s: float = 0.300,
                           idle_w: float = 60.0, peak_w: float = 250.0,
                           n_steps: int = 6) -> ActivityTimeline:
    """A slice of a diurnal utilisation cycle: the device's load follows a
    sinusoidal day curve sampled at a random phase (hour of day), stepped
    into plateaus — the slow-varying counterpart to the bursty scenarios.
    """
    rng = np.random.default_rng(seed)
    phase = float(rng.uniform(0.0, 2.0 * np.pi))
    depth = float(rng.uniform(0.5, 0.9))
    hours = phase + np.linspace(0.0, np.pi / 3.0, n_steps)   # ~4 h slice
    util = 0.5 * (1.0 + np.sin(hours)) * depth
    dwell = window_s / n_steps
    segs = [(dwell, amplitude_for_fraction(float(u), idle_w, peak_w))
            for u in util]
    return from_segments(segs, idle_w=idle_w)


SCENARIOS = {
    "training": training_step_timeline,
    "inference": inference_serving_timeline,
    "idle": idle_maintenance_timeline,
    "diurnal": diurnal_cycle_timeline,
}

DEFAULT_MIX = {"training": 0.40, "inference": 0.30,
               "idle": 0.15, "diurnal": 0.15}


def scenario_timeline(kind: str, seed: int = 0, idle_w: float = 60.0,
                      peak_w: float = 250.0) -> ActivityTimeline:
    """One device's repetition fragment for a named scenario."""
    try:
        builder = SCENARIOS[kind]
    except KeyError:
        raise KeyError(f"unknown scenario '{kind}'; "
                       f"available: {sorted(SCENARIOS)}") from None
    return builder(seed=seed, idle_w=idle_w, peak_w=peak_w)


def mixed_fleet_workloads(n: int, mix: dict[str, float] | None = None,
                          seed: int = 0, idle_w: float = 60.0,
                          peak_w: float = 250.0) -> list:
    """N per-device workloads drawn from a scenario mix — every device its
    own timeline, labelled for per-scenario error breakdowns.

    ``mix`` maps scenario name → fraction (normalised); counts are
    apportioned deterministically (largest remainder) and the assignment
    is shuffled so profiles and scenarios decorrelate.  Returns a list of
    :class:`repro.core.meter.Workload` ready for ``fleet_audit`` /
    ``measure_*_batch``.
    """
    from repro.core.meter import Workload

    if n < 1:
        raise ValueError("need at least one device")
    mix = dict(DEFAULT_MIX if mix is None else mix)
    for kind in mix:
        if kind not in SCENARIOS:
            raise KeyError(f"unknown scenario '{kind}'; "
                           f"available: {sorted(SCENARIOS)}")
    total = sum(mix.values())
    if total <= 0:
        raise ValueError("scenario mix fractions must sum to > 0")
    kinds = sorted(mix)
    exact = np.array([mix[k] / total * n for k in kinds])
    counts = np.floor(exact).astype(int)
    rema = exact - counts
    for i in np.argsort(-rema)[: n - int(counts.sum())]:
        counts[i] += 1
    labels = [k for k, c in zip(kinds, counts) for _ in range(int(c))]
    rng = np.random.default_rng(seed)
    labels = [labels[i] for i in rng.permutation(n)]
    return [
        Workload(f"{kind}[{i}]",
                 scenario_timeline(kind, seed=seed + 1 + i,
                                   idle_w=idle_w, peak_w=peak_w),
                 scenario=kind)
        for i, kind in enumerate(labels)
    ]
