"""Dependency-free Nelder–Mead simplex minimiser.

The paper fits the boxcar-window size by minimising an MSE loss with
Nelder–Mead (§4.3 step 6); scipy is not on the image, so we carry our own.
Supports box bounds via clipping at evaluation time.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence

import numpy as np


@dataclasses.dataclass
class NMResult:
    x: np.ndarray
    fun: float
    nit: int
    nfev: int
    converged: bool


def minimize(f: Callable[[np.ndarray], float],
             x0: Sequence[float],
             *,
             initial_step: float | Sequence[float] = 0.25,
             bounds: Optional[Sequence[tuple[float, float]]] = None,
             xatol: float = 1e-6,
             fatol: float = 1e-9,
             max_iter: int = 500) -> NMResult:
    x0 = np.asarray(x0, dtype=np.float64)
    n = x0.size
    lo = hi = None
    if bounds is not None:
        lo = np.asarray([b[0] for b in bounds], dtype=np.float64)
        hi = np.asarray([b[1] for b in bounds], dtype=np.float64)

    def clip(x: np.ndarray) -> np.ndarray:
        if lo is None:
            return x
        return np.clip(x, lo, hi)

    nfev = 0

    def feval(x: np.ndarray) -> float:
        nonlocal nfev
        nfev += 1
        return float(f(clip(x)))

    # initial simplex
    steps = np.broadcast_to(np.asarray(initial_step, dtype=np.float64), (n,))
    simplex = [x0.copy()]
    for i in range(n):
        v = x0.copy()
        v[i] += steps[i] if steps[i] != 0 else 0.05
        simplex.append(v)
    simplex = np.asarray(simplex)
    fvals = np.asarray([feval(v) for v in simplex])

    alpha, gamma, rho, sigma = 1.0, 2.0, 0.5, 0.5
    it = 0
    for it in range(1, max_iter + 1):
        order = np.argsort(fvals)
        simplex, fvals = simplex[order], fvals[order]
        if (np.max(np.abs(simplex[1:] - simplex[0])) <= xatol
                and np.max(np.abs(fvals[1:] - fvals[0])) <= fatol):
            return NMResult(clip(simplex[0]), fvals[0], it, nfev, True)

        centroid = simplex[:-1].mean(axis=0)
        xr = centroid + alpha * (centroid - simplex[-1])
        fr = feval(xr)
        if fvals[0] <= fr < fvals[-2]:
            simplex[-1], fvals[-1] = xr, fr
        elif fr < fvals[0]:
            xe = centroid + gamma * (xr - centroid)
            fe = feval(xe)
            if fe < fr:
                simplex[-1], fvals[-1] = xe, fe
            else:
                simplex[-1], fvals[-1] = xr, fr
        else:
            xc = centroid + rho * (simplex[-1] - centroid)
            fc = feval(xc)
            if fc < fvals[-1]:
                simplex[-1], fvals[-1] = xc, fc
            else:  # shrink
                for i in range(1, n + 1):
                    simplex[i] = simplex[0] + sigma * (simplex[i] - simplex[0])
                    fvals[i] = feval(simplex[i])

    order = np.argsort(fvals)
    return NMResult(clip(simplex[order][0]), fvals[order][0], it, nfev, False)


def minimize_scalar(f: Callable[[float], float], x0: float, *,
                    lo: float, hi: float, initial_step: float | None = None,
                    max_iter: int = 200) -> NMResult:
    """1-D convenience wrapper (what the boxcar fit uses)."""
    step = initial_step if initial_step is not None else 0.25 * (hi - lo)
    res = minimize(lambda v: f(float(v[0])), [x0], initial_step=step,
                   bounds=[(lo, hi)], xatol=1e-7, max_iter=max_iter)
    return res
