"""Energy measurement protocols: naive vs the paper's good practice (§5).

Naive (what the surveyed literature does): run the workload once, integrate
the sensor readings over the execution window, trust the result.

Good practice (§5.1, steps 1–3):
  1. ≥32 repetitions or ≥5 s total; if the averaging window is a fraction
     of the update period (A100/H100-style part-time sampling), insert 8
     evenly-spaced controlled delays of one window-length to phase-shift
     activity across the unsampled portion.
  2. 4 separate trials with randomised inter-trial delay.
  3. Discard repetitions inside the rise time; shift the sensor series to
     re-synchronise with device activity; (optionally) invert the
     calibrated gain/offset transform.
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Dict, List, Optional, Sequence, Union

import numpy as np

from repro.core.calibrate import CalibrationRecord
from repro.core.ground_truth import ActivityTimeline, TimelineBank
from repro.core.sensor import OnboardSensor

if TYPE_CHECKING:  # avoid a circular import; banks are duck-typed below
    from repro.core.fleet_engine import SensorBank


@dataclasses.dataclass(frozen=True)
class Workload:
    """One repetition of a measurable workload.

    ``scenario`` is an optional grouping label (e.g. ``"training"`` /
    ``"inference"``) used by fleet audits for per-scenario error
    breakdowns; it defaults to the workload name.
    """

    name: str
    timeline: ActivityTimeline        # fragment starting at t=0
    scenario: Optional[str] = None

    def __post_init__(self):
        if self.duration_s <= 0.0:
            raise ValueError(
                f"workload '{self.name}' has zero/negative duration "
                f"({self.duration_s} s); a repetition must cover time")

    @property
    def duration_s(self) -> float:
        return self.timeline.t_end - self.timeline.t_start

    @property
    def true_energy_j(self) -> float:
        """Analytic per-repetition ground truth."""
        return self.timeline.energy()

    @property
    def scenario_label(self) -> str:
        return self.scenario if self.scenario is not None else self.name


class WorkloadSet:
    """Per-device workloads for a heterogeneous fleet.

    Device ``i`` of a :class:`~repro.core.fleet_engine.SensorBank` runs
    ``workloads[i]`` — its own timeline, duration and analytic truth.  The
    batched measurement protocols accept this in place of a single shared
    :class:`Workload`.

    Two constructions, one contract:

    * from a sequence of :class:`Workload` objects — timelines are
      stacked once into a :class:`TimelineBank` and reused across trials;
    * bank-native (``WorkloadSet(bank=..., scenarios=...)``) — the
      :class:`TimelineBank` *is* the source of truth (durations and
      analytic energies are computed vectorized from it, identical to
      the per-object values by the bank's bitwise row contract), and
      ``Workload`` views are materialised lazily only if indexed.  This
      is what :func:`repro.core.load.mixed_fleet_workloads(as_bank=True)
      <repro.core.load.mixed_fleet_workloads>` returns: no per-device
      Python objects anywhere on the fleet-audit hot path.
    """

    def __init__(self, workloads: Optional[Sequence[Workload]] = None, *,
                 bank: Optional[TimelineBank] = None,
                 scenarios: Optional[Sequence[str]] = None):
        if (workloads is None) == (bank is None):
            raise ValueError("pass exactly one of workloads= or bank=")
        if bank is not None:
            self._workloads: Optional[List[Workload]] = None
            self._bank = bank
            self.durations_s = bank.duration_s
            self.true_energies_j = bank.energy()
            if scenarios is None:
                scenarios = [f"workload[{i}]" for i in range(bank.n_rows)]
            elif len(scenarios) != bank.n_rows:
                raise ValueError(f"{len(scenarios)} scenario labels for "
                                 f"{bank.n_rows} bank rows")
            self.scenarios = np.asarray(scenarios, dtype=object)
            return
        self._workloads = list(workloads)
        if not self._workloads:
            raise ValueError("empty WorkloadSet")
        self.durations_s = np.array([w.duration_s for w in self._workloads])
        self.true_energies_j = np.array(
            [w.true_energy_j for w in self._workloads])
        self.scenarios = np.asarray(
            [w.scenario_label for w in self._workloads], dtype=object)
        self._bank: Optional[TimelineBank] = None

    def __len__(self) -> int:
        return (len(self._workloads) if self._workloads is not None
                else self._bank.n_rows)

    def __getitem__(self, i: int) -> Workload:
        if self._workloads is not None:
            return self._workloads[i]
        return Workload(f"{self.scenarios[i]}[{i}]", self._bank.row(i),
                        scenario=str(self.scenarios[i]))

    def rows(self, lo: int, hi: int) -> "WorkloadSet":
        """The device slab ``lo .. hi-1`` as its own set (bank rows are
        sliced, not re-derived — used by chunked fleet audits)."""
        return WorkloadSet(bank=self.timeline_bank.rows(np.arange(lo, hi)),
                           scenarios=self.scenarios[lo:hi])

    @property
    def timeline_bank(self) -> TimelineBank:
        """The stacked [N, S] timeline substrate (built once, cached)."""
        if self._bank is None:
            self._bank = TimelineBank.from_timelines(
                [w.timeline for w in self._workloads])
        return self._bank


@dataclasses.dataclass(frozen=True)
class GoodPracticeConfig:
    min_reps: int = 32
    min_total_s: float = 5.0
    n_phase_shifts: int = 8
    n_trials: int = 4
    discard_rise: bool = True
    time_shift: bool = True
    apply_calibration: bool = False
    poll_period_s: float = 0.001
    max_reps: int = 4096


@dataclasses.dataclass
class EnergyEstimate:
    joules_per_rep: float
    std_j: float
    n_trials: int
    n_reps: int
    trial_values: List[float]

    def error_vs(self, truth_j: float) -> float:
        return (self.joules_per_rep - truth_j) / truth_j


class ModuleScopeError(RuntimeError):
    """Raised when a module-scope sensor (GH200 `instant`, §6) would be
    attributed to chip-level energy without a host baseline."""


def _integrate_readings(ts: np.ndarray, vals: np.ndarray,
                        t0: float, t1: float) -> float:
    """Step-integrate the polled reading series over [t0, t1].

    Thin scalar wrapper over the shared batched kernel
    (:func:`repro.core.engine_backend.numpy_backend.step_integrate`) —
    the single rectangle-rule implementation behind both this offline §5
    protocol and the streaming monitor's online accumulation.
    """
    from repro.core.engine_backend.numpy_backend import step_integrate
    return float(step_integrate(
        np.asarray(ts, dtype=np.float64)[None, :],
        np.asarray(vals, dtype=np.float64)[None, :],
        np.array([t0], dtype=np.float64),
        np.array([t1], dtype=np.float64))[0])


def _check_scope(sensor: OnboardSensor, host_baseline_w: Optional[float]) -> float:
    if sensor.profile.scope == "module" and host_baseline_w is None:
        raise ModuleScopeError(
            f"profile '{sensor.profile.name}' measures the whole module "
            "(GPU+CPU+DRAM); supply host_baseline_w to subtract, or use a "
            "chip-scope profile")
    return host_baseline_w or 0.0


def measure_naive(sensor: OnboardSensor, workload: Workload,
                  start_offset_s: float = 0.3,
                  host_baseline_w: Optional[float] = None,
                  poll_period_s: float = 0.001) -> float:
    """Single run; integrate sensor power over the execution window only."""
    baseline = _check_scope(sensor, host_baseline_w)
    tl = workload.timeline.shift(start_offset_s - workload.timeline.t_start)
    sensor.attach(tl, t_end=tl.t_end + 1.0)
    ts, vals = sensor.poll(0.0, tl.t_end + 0.5, period_s=poll_period_s)
    vals = vals - baseline
    return _integrate_readings(ts, vals, start_offset_s,
                               start_offset_s + workload.duration_s)


def measure_good_practice(sensor: OnboardSensor, workload: Workload,
                          calib: CalibrationRecord,
                          cfg: GoodPracticeConfig = GoodPracticeConfig(),
                          host_baseline_w: Optional[float] = None,
                          seed: int = 0) -> EnergyEstimate:
    """The paper's protocol; returns a per-repetition energy estimate."""
    baseline = _check_scope(sensor, host_baseline_w)
    rng = np.random.default_rng(seed)
    dur = workload.duration_s
    reps = int(_reps_for(dur, cfg))

    part_time = (calib.sampled_fraction < 0.999)
    W = calib.time_shift_s
    shifts = cfg.n_phase_shifts if part_time else 0

    trial_values: List[float] = []
    for trial in range(cfg.n_trials):
        start = 0.3 + float(rng.uniform(0.0, 1.0))      # randomised delay
        train = _build_train(workload.timeline, reps, shifts, W)
        train = train.shift(start - train.t_start)
        sensor.attach(train, t_end=train.t_end + 2.0)
        ts, vals = sensor.poll(0.0, train.t_end + 1.0,
                               period_s=cfg.poll_period_s)
        vals = vals - baseline
        if cfg.apply_calibration and calib.gain:
            vals = (vals - (calib.offset_w or 0.0)) / calib.gain
        if cfg.time_shift:
            ts = ts - W                 # reading at t covers [t-W, t]

        # discard repetitions inside the rise time
        rise = calib.rise_time_s if (cfg.discard_rise and
                                     np.isfinite(calib.rise_time_s)) else 0.0
        n_skip = int(np.ceil(rise / max(dur, 1e-6)))
        n_skip = min(n_skip, reps - 1)
        # locate kept-rep span inside the train (account for inserted gaps)
        kept = reps - n_skip
        t_begin = start + _train_offset(n_skip, dur, shifts, reps, W)
        t_end = start + _train_offset(reps, dur, shifts, reps, W)
        e = _integrate_readings(ts, vals, t_begin, t_end)
        # subtract the idle energy of the inserted gaps inside the span
        gaps_inside = _gaps_between(n_skip, reps, shifts, reps)
        e -= gaps_inside * W * workload.timeline.idle_w
        trial_values.append(e / kept)

    arr = np.asarray(trial_values)
    return EnergyEstimate(float(np.mean(arr)), float(np.std(arr)),
                          cfg.n_trials, reps, trial_values)


def _build_train(timeline: ActivityTimeline, reps: int, shifts: int,
                 W: float) -> ActivityTimeline:
    """The §5.1 repetition train: ``reps`` back-to-back repetitions, with
    an idle gap of one window-length after every complete group when
    phase-shift delays are in play (part-time sensors)."""
    if shifts > 0:
        group = max(1, reps // shifts)
        parts = []
        done = 0
        while done < reps:
            k = min(group, reps - done)
            parts.append(timeline.repeat(k))
            done += k
        return ActivityTimeline.concat(parts, gap_s=W)
    return timeline.repeat(reps)


def _train_arrays(timeline: ActivityTimeline, reps: int, shifts: int,
                  W: float):
    """(edges, powers) of the §5.1 repetition train, built directly as
    flat arrays — the array-programming form of :func:`_build_train`
    (which stacks ``ActivityTimeline.concat`` calls).  Values agree to
    float rounding (~1e-13 of the train length): the only difference is
    the repetition offsets coming from ``r·dur`` instead of a sequentially
    accumulated cursor.
    """
    rel = timeline.edges - timeline.t_start          # [S+1], starts at 0
    p = timeline.powers
    s = len(p)
    dur = float(rel[-1])
    r = np.arange(reps)
    if shifts > 0:
        group = max(1, reps // shifts)
        gaps = np.minimum(r // group, (reps - 1) // group)
    else:
        gaps = np.zeros(reps, dtype=np.int64)
    off = r * dur + gaps * W                          # start of rep r
    starts = (rel[None, :s] + off[:, None]).ravel()
    powers = np.tile(p, reps)
    gap_rows = np.nonzero(np.diff(gaps) > 0)[0] + 1   # reps preceded by a gap
    if len(gap_rows):
        pos = gap_rows * s
        starts = np.insert(starts, pos, off[gap_rows] - W)
        powers = np.insert(powers, pos, timeline.idle_w)
    edges = np.concatenate([starts, [off[-1] + dur]]) + timeline.t_start
    return edges, powers


def _train_bank(ws: WorkloadSet, rows: np.ndarray, reps: np.ndarray,
                shifts: int, W: float) -> TimelineBank:
    """Stack per-device repetition trains into a :class:`TimelineBank`
    without materialising intermediate ActivityTimeline objects."""
    built = [_train_arrays(ws[i].timeline, int(reps[g]), shifts, W)
             for g, i in enumerate(rows)]
    n_segs = np.array([len(p) for _, p in built], dtype=np.int64)
    smax = int(n_segs.max())
    edges = np.empty((len(built), smax + 1))
    powers = np.empty((len(built), smax))
    idle = np.array([ws[i].timeline.idle_w for i in rows])
    for g, (e, p) in enumerate(built):
        k = len(p)
        edges[g, :k + 1] = e
        edges[g, k + 1:] = e[-1]
        powers[g, :k] = p
        powers[g, k:] = idle[g]
    return TimelineBank(edges, powers, idle, n_segs)


def _reps_for(durations, cfg: GoodPracticeConfig) -> np.ndarray:
    """Per-device repetition counts (≥ min_reps, ≥ min_total_s of runtime,
    capped at max_reps) — the scalar formula, vectorised."""
    dur = np.asarray(durations, dtype=np.float64)
    reps = np.maximum(cfg.min_reps,
                      np.ceil(cfg.min_total_s
                              / np.maximum(dur, 1e-6)).astype(np.int64))
    return np.minimum(reps, cfg.max_reps)


def _n_gaps_before(rep_idx: int, shifts: int, reps: int) -> int:
    """Number of inserted W-gaps before the start of repetition ``rep_idx``.

    A gap follows every complete group of ``reps // shifts`` repetitions,
    with no gap after the final repetition.
    """
    if shifts <= 0:
        return 0
    group = max(1, reps // shifts)
    return min(rep_idx // group, (reps - 1) // group)


def _train_offset(rep_idx: int, dur: float, shifts: int, reps: int,
                  W: float) -> float:
    """Wall-clock offset of the start of repetition ``rep_idx`` (or, for
    ``rep_idx == reps``, the end of the train)."""
    return rep_idx * dur + _n_gaps_before(rep_idx, shifts, reps) * W


def _gaps_between(i0: int, i1: int, shifts: int, reps: int) -> int:
    """Inserted gaps lying between the start of rep i0 and end of rep i1-1."""
    return (_n_gaps_before(i1, shifts, reps)
            - _n_gaps_before(i0, shifts, reps))


# ---------------------------------------------------------------------------
# Batched protocols: whole trial matrices dispatched through a SensorBank
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class BatchedEnergyEstimate:
    """Per-device good-practice estimates for a whole fleet."""

    joules_per_rep: np.ndarray     # [N]
    std_j: np.ndarray              # [N]
    n_trials: int
    n_reps: np.ndarray             # [N]
    trial_values: np.ndarray       # [N, n_trials]

    def error_vs(self, truth_j: float) -> np.ndarray:
        return (self.joules_per_rep - truth_j) / truth_j

    def device(self, i: int) -> EnergyEstimate:
        """The scalar view of one device's estimate."""
        return EnergyEstimate(float(self.joules_per_rep[i]),
                              float(self.std_j[i]), self.n_trials,
                              int(self.n_reps[i]),
                              [float(v) for v in self.trial_values[i]])


def _check_scope_bank(bank: "SensorBank",
                      host_baseline_w: Optional[float]) -> float:
    if np.any(bank.module_scope) and host_baseline_w is None:
        name = bank.profiles[int(np.argmax(bank.module_scope))].name
        raise ModuleScopeError(
            f"profile '{name}' measures the whole module (GPU+CPU+DRAM); "
            "supply host_baseline_w to subtract, or use a chip-scope profile")
    return host_baseline_w or 0.0


def _baseline_rows(bank: "SensorBank", baseline: float) -> np.ndarray:
    """Per-device baseline [N]: the host baseline is debited from
    module-scope rows only — chip-scope sensors never see host power, so
    a mixed fleet must not subtract it from their readings."""
    return np.where(bank.module_scope, baseline, 0.0)


def as_workload_set(workload: Union[Workload, Sequence[Workload],
                                    WorkloadSet],
                    n_devices: int) -> Optional[WorkloadSet]:
    """Normalise a protocol's workload argument: ``None`` for one shared
    :class:`Workload`, else a :class:`WorkloadSet` checked against the
    fleet size."""
    if isinstance(workload, Workload):
        return None
    ws = workload if isinstance(workload, WorkloadSet) \
        else WorkloadSet(workload)
    if len(ws) != n_devices:
        raise ValueError(f"{len(ws)} workloads for {n_devices} devices")
    return ws


def measure_naive_batch(bank: "SensorBank",
                        workload: Union[Workload, Sequence[Workload],
                                        WorkloadSet],
                        start_offset_s: float = 0.3,
                        host_baseline_w: Optional[float] = None,
                        poll_period_s: float = 0.001,
                        backend: Optional[str] = None) -> np.ndarray:
    """Batched :func:`measure_naive`: every device's sensor integrated at
    once; returns per-device joules [N].

    ``workload`` is one shared :class:`Workload` (every device runs the
    same job, the degenerate case) or a :class:`WorkloadSet` /sequence of
    per-device workloads — a heterogeneous fleet measured in one pass.
    Device ``i`` reproduces ``measure_naive(bank.scalar_reference(i),
    workload_i)`` on its own timeline (with ``host_baseline_w`` passed
    through for module-scope devices only).  ``backend`` overrides the
    bank's execution backend for this measurement
    (``"numpy"``/``"jax"``/``"auto"``, see :mod:`repro.core.engine_backend`).
    """
    if backend is not None:
        bank = bank.with_backend(backend)
    baseline = _check_scope_bank(bank, host_baseline_w)
    base = _baseline_rows(bank, baseline)
    if baseline and np.any(base):
        def transform(v, base=base):
            return v - (base if v.ndim == 1 else base[:, None])
    else:
        transform = None
    ws = as_workload_set(workload, bank.n_devices)
    if ws is None:
        tl = workload.timeline.shift(start_offset_s
                                     - workload.timeline.t_start)
        bank.attach(tl, t_end=tl.t_end + 1.0)
        return bank.integrate_polled(
            0.0, tl.t_end + 0.5, poll_period_s,
            start_offset_s, start_offset_s + workload.duration_s,
            transform=transform)
    tlb = ws.timeline_bank
    tlb = tlb.shift(start_offset_s - tlb.t_start)
    bank.attach(tlb, t_end=tlb.t_end + 1.0)
    return bank.integrate_polled(
        0.0, tlb.t_end + 0.5, poll_period_s,
        start_offset_s, start_offset_s + ws.durations_s,
        transform=transform)


def measure_good_practice_batch(
        bank: "SensorBank",
        workload: Union[Workload, Sequence[Workload], WorkloadSet],
        calib: Union[CalibrationRecord, Dict[str, CalibrationRecord]],
        cfg: GoodPracticeConfig = GoodPracticeConfig(),
        host_baseline_w: Optional[float] = None,
        seeds: Optional[np.ndarray] = None,
        backend: Optional[str] = None) -> BatchedEnergyEstimate:
    """Batched §5 protocol: each trial dispatches the whole fleet's reading
    matrix at once instead of looping devices.

    Devices are grouped by profile name (the repetition train layout
    depends on the calibration's window); within a group the per-device
    randomised start offsets become a vectorised timeline shift.  Device
    ``i`` gets protocol seed ``seeds[i]`` and reproduces
    ``measure_good_practice(bank.scalar_reference(i), ..., seed=seeds[i])``
    within one reporting quantum.  ``calib`` is one record (homogeneous
    fleet) or a dict keyed by profile name.

    With a :class:`WorkloadSet` every device runs *its own* workload: the
    per-device repetition trains are stacked into a
    :class:`TimelineBank` per profile group, and repetition counts, rise
    discards and gap corrections all become per-device vectors.
    ``backend`` overrides the bank's execution backend for this
    measurement (the per-profile sub-banks inherit it).
    """
    if backend is not None:
        bank = bank.with_backend(backend)
    n = bank.n_devices
    baseline = _check_scope_bank(bank, host_baseline_w)
    ws = as_workload_set(workload, n)
    if seeds is None:
        seeds = np.arange(n)
    seeds = np.asarray(seeds, dtype=np.int64)
    calibs: Dict[str, CalibrationRecord]
    if isinstance(calib, CalibrationRecord):
        calibs = {p.name: calib for p in bank.profiles}
    else:
        calibs = calib

    joules = np.zeros(n)
    stds = np.zeros(n)
    reps_out = np.zeros(n, dtype=np.int64)
    trials = np.zeros((n, cfg.n_trials))
    names = np.array([p.name for p in bank.profiles])
    for name in sorted(set(names)):
        rows = np.nonzero(names == name)[0]
        sub = bank.subset(rows)
        cal = calibs[name]
        part_time = (cal.sampled_fraction < 0.999)
        W = cal.time_shift_s
        shifts = cfg.n_phase_shifts if part_time else 0
        rise = cal.rise_time_s if (cfg.discard_rise and
                                   np.isfinite(cal.rise_time_s)) else 0.0

        # per-device randomised trial start offsets (same default_rng(seed)
        # stream as the scalar protocol, drawn n_trials at a time, via
        # lock-step vectorized streams — bitwise the per-device draws)
        from repro.core.engine_backend.vecrng import VecStreams
        starts = 0.3 + VecStreams(seeds[rows]).uniform_block(
            0.0, 1.0, np.full(len(rows), cfg.n_trials))

        base = _baseline_rows(sub, baseline)

        def transform(v, cal=cal, base=base):
            v = v - (base if v.ndim == 1 else base[:, None])
            if cfg.apply_calibration and cal.gain:
                v = (v - (cal.offset_w or 0.0)) / cal.gain
            return v

        if ws is None:
            dur = workload.duration_s
            reps = int(_reps_for(dur, cfg))
            # repetition train, identical to the scalar path, built once
            train = _build_train(workload.timeline, reps, shifts, W)
            n_skip = min(int(np.ceil(rise / max(dur, 1e-6))), reps - 1)
            kept = reps - n_skip
            off_begin = _train_offset(n_skip, dur, shifts, reps, W)
            off_end = _train_offset(reps, dur, shifts, reps, W)
            gaps_inside = _gaps_between(n_skip, reps, shifts, reps)
            idle = workload.timeline.idle_w
            reps_out[rows] = reps
            length = train.t_end - train.t_start
            for t in range(cfg.n_trials):
                start = starts[:, t]
                shift = start - train.t_start
                sub.attach(train, t_end=train.t_end + shift + 2.0,
                           shifts=shift)
                e = sub.integrate_polled(
                    0.0, start + length + 1.0, cfg.poll_period_s,
                    start + off_begin, start + off_end,
                    transform=transform,
                    grid_offset=-W if cfg.time_shift else 0.0)
                e -= gaps_inside * W * idle
                trials[rows, t] = e / kept
        else:
            dur = ws.durations_s[rows]
            reps = _reps_for(dur, cfg)
            n_skip = np.minimum(
                np.ceil(rise / np.maximum(dur, 1e-6)).astype(np.int64),
                reps - 1)
            kept = reps - n_skip
            # vectorized _train_offset/_gaps_between (same arithmetic)
            if shifts > 0:
                group = np.maximum(1, reps // shifts)
                gb = np.minimum(n_skip // group, (reps - 1) // group)
                ge = np.minimum(reps // group, (reps - 1) // group)
            else:
                gb = ge = np.zeros(len(rows), dtype=np.int64)
            off_begin = n_skip * dur + gb * W
            off_end = reps * dur + ge * W
            gaps_inside = (ge - gb).astype(np.float64)
            tb0 = _train_bank(ws, rows, reps, shifts, W)
            idle = tb0.idle_w
            reps_out[rows] = reps
            for t in range(cfg.n_trials):
                start = starts[:, t]
                tb = tb0.shift(start - tb0.t_start)
                sub.attach(tb, t_end=tb.t_end + 2.0)
                e = sub.integrate_polled(
                    0.0, tb.t_end + 1.0, cfg.poll_period_s,
                    start + off_begin, start + off_end,
                    transform=transform,
                    grid_offset=-W if cfg.time_shift else 0.0)
                e -= gaps_inside * W * idle
                trials[rows, t] = e / kept

        joules[rows] = np.mean(trials[rows], axis=1)
        stds[rows] = np.std(trials[rows], axis=1)

    return BatchedEnergyEstimate(joules, stds, cfg.n_trials,
                                 reps_out, trials)


def compare_protocols(sensor: OnboardSensor, workload: Workload,
                      calib: CalibrationRecord,
                      cfg: GoodPracticeConfig = GoodPracticeConfig(),
                      seed: int = 0,
                      host_baseline_w: Optional[float] = None) -> dict:
    """Fig. 18: naive error vs good-practice error for one workload."""
    truth = workload.true_energy_j
    naive = measure_naive(sensor, workload, host_baseline_w=host_baseline_w,
                          start_offset_s=0.3 + (seed % 17) * 0.037)
    gp = measure_good_practice(sensor, workload, calib, cfg, seed=seed,
                               host_baseline_w=host_baseline_w)
    return {
        "workload": workload.name,
        "truth_j": truth,
        "naive_j": naive,
        "naive_err": (naive - truth) / truth,
        "gp_j": gp.joules_per_rep,
        "gp_err": gp.error_vs(truth),
        "gp_std_j": gp.std_j,
    }
