"""Black-box sensor characterisation (the paper's §4 experiments).

Every estimator here sees only the public query API of an
:class:`OnboardSensor` (plus, where the paper used one, a ground-truth
meter).  The hidden profile parameters are recovered:

* :func:`estimate_update_period`   — Fig. 6  (median run-length of constant readings)
* :func:`measure_transient`        — Fig. 7  (rise time + response class)
* :func:`estimate_steady_state`    — Fig. 8/9 (gain & offset by regression)
* :func:`estimate_boxcar_window`   — Figs. 10–13 (aliased square wave +
  boxcar emulation + Nelder–Mead MSE fit)
* :func:`characterise`             — the full suite → CalibrationRecord
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence

import numpy as np

from repro.core import load as loads
from repro.core import neldermead
from repro.core.ground_truth import ActivityTimeline, GroundTruthMeter
from repro.core.sensor import OnboardSensor


# ---------------------------------------------------------------------------
# 4.1 Power update period
# ---------------------------------------------------------------------------

def complete_run_durations(ts: np.ndarray, vals: np.ndarray) -> np.ndarray:
    """Durations of *complete* runs of identical consecutive readings.

    A run is complete when it is bounded by a reading change on both
    sides: the first run starts at the poll grid's origin, not at a
    reading boundary (the sensor's phase truncates it by up to one
    period), and the last run is cut off by the capture end — both are
    dropped, the rule shared by the offline estimator below and the
    streaming monitor's online estimator
    (:class:`repro.core.stream.OnlinePeriodEstimator`), which extracts
    the same change-to-change durations sample-by-sample.
    """
    ts = np.asarray(ts, dtype=np.float64)
    vals = np.asarray(vals)
    change = np.flatnonzero(np.diff(vals) != 0.0)
    if len(change) < 2:
        return np.empty(0)
    return np.diff(ts[change])


def estimate_update_period(sensor: OnboardSensor,
                           query_period_s: float = 0.001,
                           duration_s: float = 8.0,
                           p_high: float = 220.0,
                           p_low: float = 70.0) -> float:
    """Drive a fast square wave and measure how often readings change.

    The paper queries at ~1 ms with a 20 ms square-wave load and takes the
    median length of runs of identical readings — complete runs only
    (see :func:`complete_run_durations`); fewer than three cannot
    support a median and report nan.
    """
    wave = loads.square_wave(period_s=0.020,
                             n_cycles=int(duration_s / 0.020),
                             p_high=p_high, p_low=p_low, seed=11)
    sensor.attach(wave, t_end=duration_s)
    ts, vals = sensor.poll(0.0, duration_s, period_s=query_period_s)
    periods = complete_run_durations(ts, vals)
    if len(periods) < 3:
        return float("nan")
    return float(np.median(periods))


# ---------------------------------------------------------------------------
# 4.2 Transient response
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TransientResult:
    kind: str            # instant | linear | logarithmic
    rise_time_s: float   # 10 % -> 90 %
    delay_s: float       # load start -> first reading movement
    settle_w: float


def measure_transient(sensor: OnboardSensor,
                      update_period_s: float,
                      p_high: float = 220.0,
                      p_low: float = 70.0) -> TransientResult:
    """Single 6 s step (paper §4.2); classify the response shape."""
    t_on = 0.5
    tl = loads.step(t_on=t_on, duration_s=6.0, p_high=p_high, p_low=p_low)
    sensor.attach(tl, t_end=8.0)
    ts, vals = sensor.poll(0.0, 7.5, period_s=0.001)

    base = np.median(vals[ts < t_on])
    settle = np.median(vals[(ts > t_on + 4.0) & (ts < t_on + 5.5)])
    span = settle - base
    if abs(span) < 1.0:
        return TransientResult("flat", float("nan"), float("nan"), settle)

    def first_crossing(frac: float) -> float:
        thresh = base + frac * span
        after = ts > t_on
        hit = np.flatnonzero(after & (vals >= thresh))
        return float(ts[hit[0]]) if len(hit) else float("nan")

    t10, t90 = first_crossing(0.10), first_crossing(0.90)
    rise = t90 - t10
    delay = first_crossing(0.05) - t_on

    # classification: within ~1 update period => the sensor publishes the
    # new level at its next tick ("instant"); ~1 s linear ramp => running
    # 1 s average; slower smooth approach => logarithmic capacitor charge
    if rise <= 1.5 * update_period_s:
        kind = "instant"
    else:
        # discriminate linear vs logarithmic by curvature of the ramp
        sel = (ts >= t10) & (ts <= t90)
        x = (ts[sel] - t10) / max(rise, 1e-9)
        y = (vals[sel] - base) / span
        # fit y = a·x + b and y = 1 - exp(-x/tau)-style; compare residuals
        lin_res = _residual(x, y, lambda x_, p: p[0] * x_ + p[1],
                            [(0.5, 1.5), (-0.5, 0.5)])
        log_res = _residual(x, y, lambda x_, p: 1.0 - np.exp(-x_ / np.maximum(p[0], 1e-3)),
                            [(0.05, 2.0)])
        kind = "linear" if lin_res <= log_res else "logarithmic"
    return TransientResult(kind, rise, delay, settle)


def _residual(x: np.ndarray, y: np.ndarray,
              model: Callable[[np.ndarray, np.ndarray], np.ndarray],
              bounds: Sequence[tuple[float, float]]) -> float:
    x0 = [0.5 * (lo + hi) for lo, hi in bounds]
    res = neldermead.minimize(
        lambda p: float(np.mean((model(x, p) - y) ** 2)),
        x0, bounds=bounds, initial_step=[0.2] * len(x0), max_iter=200)
    return res.fun


# ---------------------------------------------------------------------------
# 4.2 Steady-state error (needs a ground-truth meter, like the paper's PMD)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SteadyStateResult:
    gain: float
    offset_w: float
    r2: float
    levels_sensor: np.ndarray
    levels_truth: np.ndarray


def estimate_steady_state(sensor: OnboardSensor,
                          meter: GroundTruthMeter,
                          fractions: Sequence[float] = (0.0, 0.01, 0.2, 0.4,
                                                        0.6, 0.8, 1.0),
                          repeats: int = 8,
                          dwell_s: float = 4.0,
                          idle_w: float = 60.0,
                          peak_w: float = 250.0) -> SteadyStateResult:
    """Hold plateaus at SM-count fractions; regress sensor vs truth (Fig. 8)."""
    levels = [loads.amplitude_for_fraction(f, idle_w, peak_w)
              for f in fractions] * repeats
    tl = loads.plateaus(levels, dwell_s=dwell_s, idle_w=idle_w, gap_s=0.5)
    sensor.attach(tl)
    xs, ys = [], []
    cursor = 0.0
    for w in levels:
        # discard the first 1.5 s of each plateau (rise + averaging window)
        t0, t1 = cursor + 1.5, cursor + dwell_s
        ts = np.linspace(t0, t1, 64)
        ys.append(float(np.mean(sensor.query(ts))))
        pm_ts, pm_w = meter.trace(tl, t0, t1)
        xs.append(float(np.mean(pm_w)))
        cursor += dwell_s + 0.5
    x = np.asarray(xs)
    y = np.asarray(ys)
    A = np.stack([x, np.ones_like(x)], axis=1)
    (gain, offset), *_ = np.linalg.lstsq(A, y, rcond=None)
    pred = gain * x + offset
    ss_res = float(np.sum((y - pred) ** 2))
    ss_tot = float(np.sum((y - y.mean()) ** 2))
    r2 = 1.0 - ss_res / max(ss_tot, 1e-12)
    return SteadyStateResult(float(gain), float(offset), r2, y, x)


# ---------------------------------------------------------------------------
# 4.3 Boxcar averaging window
# ---------------------------------------------------------------------------

def _emulate_boxcar(reference: ActivityTimeline, ticks: np.ndarray,
                    window_s: float) -> np.ndarray:
    """The paper's emulation model: for each sensor timestamp, average the
    reference trace over the trailing candidate window."""
    return reference.mean_power(ticks - window_s, ticks)


def _normalise(v: np.ndarray) -> np.ndarray:
    s = np.std(v)
    return (v - np.mean(v)) / (s if s > 1e-9 else 1.0)


def estimate_boxcar_window(sensor: OnboardSensor,
                           update_period_s: float,
                           fractions: Sequence[float] = (2 / 3, 3 / 4, 4 / 5,
                                                         6 / 5, 5 / 4, 4 / 3),
                           repetitions: int = 8,
                           duration_s: float = 9.0,
                           p_high: float = 220.0,
                           p_low: float = 70.0,
                           seed: int = 0) -> tuple[float, np.ndarray]:
    """Recover W by the paper's aliasing + emulation + Nelder–Mead recipe.

    Returns (median window estimate, all samples).  The reference used for
    emulation is the *commanded square wave* — the paper shows (Fig. 12)
    this matches using PMD data, enabling PMD-free characterisation.
    """
    T = update_period_s
    estimates: List[float] = []
    rng = np.random.default_rng(seed)
    for rep in range(repetitions):
        frac = fractions[rep % len(fractions)]
        period = frac * T
        wave = loads.square_wave(
            period_s=period, n_cycles=int(duration_s / period),
            p_high=p_high, p_low=p_low,
            period_jitter_s=0.002, seed=int(rng.integers(1 << 31)))
        sensor.attach(wave, t_end=duration_s + 1.0)
        ts, vals = sensor.poll(0.0, duration_s, period_s=0.001)
        # keep one sample per sensor update: timestamps where value changed
        chg = np.flatnonzero(np.diff(vals) != 0.0) + 1
        ticks, obs = ts[chg], vals[chg]
        # discard the first second (paper step 4), need enough ticks
        keep = ticks > 1.0
        ticks, obs = ticks[keep], obs[keep]
        if len(ticks) < 8:
            continue
        obs_n = _normalise(obs)

        def loss(w: float) -> float:
            em = _emulate_boxcar(wave, ticks, max(w, 1e-4))
            return float(np.mean((_normalise(em) - obs_n) ** 2))

        # multi-start Nelder–Mead: the loss is multimodal when W ≈ T
        # (aliasing harmonics), so seed from several window fractions and
        # keep the best minimum (paper runs 32 trials × 6 fractions and
        # takes the distribution median for the same reason).
        best = None
        for x0 in (0.25 * T, 0.5 * T, 0.9 * T, 1.2 * T):
            res = neldermead.minimize_scalar(loss, x0=x0, lo=1e-3,
                                             hi=2.0 * T,
                                             initial_step=0.2 * T)
            if best is None or res.fun < best.fun:
                best = res
        estimates.append(float(best.x[0]))
    arr = np.asarray(estimates)
    return float(np.median(arr)), arr


# ---------------------------------------------------------------------------
# Full characterisation
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CharacterisationResult:
    update_period_s: float
    transient: TransientResult
    window_s: Optional[float]
    gain: Optional[float]
    offset_w: Optional[float]
    r2: Optional[float]
    sampled_fraction: float


def characterise(sensor: OnboardSensor,
                 meter: Optional[GroundTruthMeter] = None,
                 boxcar_reps: int = 8) -> CharacterisationResult:
    """Run the full micro-benchmark suite on one device."""
    T = estimate_update_period(sensor)
    tr = measure_transient(sensor, T)
    window: Optional[float] = None
    if tr.kind == "instant":
        window, _ = estimate_boxcar_window(sensor, T, repetitions=boxcar_reps)
    elif tr.kind == "linear":
        window = tr.rise_time_s  # running average over ~rise time (1 s class)
    gain = offset = r2 = None
    if meter is not None:
        ss = estimate_steady_state(sensor, meter)
        gain, offset, r2 = ss.gain, ss.offset_w, ss.r2
    frac = 1.0 if window is None else min(1.0, window / T)
    return CharacterisationResult(T, tr, window, gain, offset, r2, frac)
