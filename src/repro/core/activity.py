"""Roofline-driven activity model: compiled step → power timeline.

Bridges the framework's roofline analysis (launch/roofline.py) to the
power-measurement core: each executed step contributes an
:class:`ActivityTimeline` fragment whose power level follows the step's
compute/memory utilisation.  This is the TPU adaptation of the paper's
"SM-fraction → power amplitude" relationship (DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.ground_truth import ActivityTimeline, from_segments


@dataclasses.dataclass(frozen=True)
class ChipPowerModel:
    """Per-chip power envelope (documented assumption; see DESIGN.md §6)."""

    idle_w: float = 65.0
    peak_w: float = 250.0
    # weights of how much each engine contributes at full utilisation
    mxu_weight: float = 0.60
    hbm_weight: float = 0.30
    ici_weight: float = 0.10

    def step_power_w(self, compute_util: float, memory_util: float,
                     collective_util: float) -> float:
        u = (self.mxu_weight * min(compute_util, 1.0)
             + self.hbm_weight * min(memory_util, 1.0)
             + self.ici_weight * min(collective_util, 1.0))
        # activation floor: a running chip never sits at idle power
        floor = 0.15
        return self.idle_w + (self.peak_w - self.idle_w) * (
            floor + (1.0 - floor) * u)


@dataclasses.dataclass(frozen=True)
class StepActivity:
    """Roofline terms for one compiled step (seconds of each bottleneck)."""

    compute_s: float
    memory_s: float
    collective_s: float

    @property
    def step_time_s(self) -> float:
        # perfectly overlapped lower bound — the roofline step time
        return max(self.compute_s, self.memory_s, self.collective_s)

    def utilisations(self) -> tuple[float, float, float]:
        t = max(self.step_time_s, 1e-12)
        return (self.compute_s / t, self.memory_s / t, self.collective_s / t)


def steps_timeline(step: StepActivity, n_steps: int,
                   model: ChipPowerModel = ChipPowerModel(),
                   gap_s: float = 0.0, t0: float = 0.0) -> ActivityTimeline:
    """Activity timeline for ``n_steps`` identical steps."""
    cu, mu, xu = step.utilisations()
    p = model.step_power_w(cu, mu, xu)
    segs = []
    for _ in range(n_steps):
        segs.append((step.step_time_s, p))
        if gap_s > 0:
            segs.append((gap_s, model.idle_w))
    return from_segments(segs, t0=t0, idle_w=model.idle_w)


def phase_timeline(phases: list[StepActivity],
                   model: ChipPowerModel = ChipPowerModel(),
                   t0: float = 0.0) -> ActivityTimeline:
    """Multi-phase step (e.g. prefill burst then decode stream)."""
    segs = []
    for ph in phases:
        cu, mu, xu = ph.utilisations()
        segs.append((ph.step_time_s, model.step_power_w(cu, mu, xu)))
    return from_segments(segs, t0=t0, idle_w=model.idle_w)
