"""Three-term roofline analysis from a compiled dry-run artifact.

    compute term    = HLO_FLOPs / (chips × peak_FLOP/s)
    memory term     = HLO_bytes / (chips × HBM_bw)
    collective term = collective_bytes / (chips × link_bw)

Hardware constants (TPU v5e, per brief): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.

Methodology (full discussion in EXPERIMENTS.md §Roofline):
  * FLOPs — XLA's ``cost_analysis()`` counts a while-loop body ONCE, so a
    depth-L layer scan under-reports by ~L×.  We therefore parse the
    post-optimisation HLO and sum dot FLOPs with recovered trip counts
    (launch/hlo.py: hlo_dot_flops); raw cost_analysis numbers are kept in
    the artifact for reference.  Dot-only FLOPs are the MFU convention.
  * HBM bytes — the CPU-backend compile reports "bytes accessed" for ops
    that a TPU backend would keep fused in VMEM (e.g. the blocked-
    attention score tiles), so raw HLO bytes badly overstate HBM traffic.
    We report BOTH: the raw number and an analytic traffic model
    (params/opt/activation-checkpoint/KV/logits traffic); the bottleneck
    uses the analytic term.
  * collective bytes — parsed from HLO with while-body scaling;
    async -start/-done pairs counted once.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import numpy as np

from repro.configs.base import ArchConfig, ShapeCell
from repro.launch import hlo as hlo_mod

PEAK_FLOPS = 197e12         # bf16 per chip
HBM_BW = 819e9              # bytes/s per chip
ICI_BW = 50e9               # bytes/s per link
HBM_PER_CHIP = 16e9         # v5e HBM capacity


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    # raw per-device measurements
    cost_flops_per_device: float
    cost_bytes_per_device: float
    dot_flops_per_device: float
    coll_bytes_per_device: float
    analytic_bytes_per_device: float
    peak_memory_per_device: float
    # terms (seconds)
    compute_s: float
    memory_s: float
    memory_raw_s: float
    collective_s: float
    bottleneck: str
    # usefulness
    model_flops: float
    hlo_global_flops: float
    useful_ratio: float          # MODEL_FLOPS / HLO_FLOPs
    roofline_fraction: float     # useful-compute time / bottleneck step time
    fits_hbm: bool
    note: str = ""

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


def model_flops_for(cfg: ArchConfig, shape: ShapeCell,
                    active_params: int) -> float:
    """MODEL_FLOPS = 6·N·D (train) / 2·N·D (inference); N_active for MoE."""
    if shape.mode == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * active_params * tokens
    if shape.mode == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * active_params * tokens
    return 2.0 * active_params * shape.global_batch   # decode: 1 tok/seq


def analytic_traffic(cfg: ArchConfig, shape: ShapeCell, chips: int,
                     total_params: int, active_params: int) -> float:
    """Modelled HBM bytes per device per step (documented in EXPERIMENTS):

    train:   gathered weights read fwd+bwd (2×N_active·2B per token-batch
             pass, amortised across the batch → per device: 2·2·N_active /
             data_shards is pessimistic; we charge full gathered reads) +
             optimizer shard traffic (m, v f32 read+write + grad f32 +
             param rw ≈ 20·N_total/chips) + activation checkpoints
             (L × tokens_local × d × 2B × 2) + logits (tokens_local ×
             V/tp × 4B × 2).
    prefill: gathered weights once + activations fwd + KV writes.
    decode:  weight shard read (N_active·2B/chips... sharded weights stay
             resident; every chip reads its shard) + KV/state cache read.
    """
    B, S = shape.global_batch, shape.seq_len
    V, D, L = cfg.vocab, cfg.d_model, cfg.n_layers
    # mesh split heuristics match ShardingRules defaults
    tp = 16 if chips >= 256 else max(1, int(np.sqrt(chips)))
    dp = chips // tp
    tokens_local = max(1, (B * S) // dp) if shape.mode != "decode" else \
        max(1, B // dp)

    if shape.mode == "train":
        w = 2 * active_params * 2.0                  # fwd+bwd gathered reads
        opt = 20.0 * total_params / chips            # f32 m,v,grad,param rw
        act = L * tokens_local * D * 2.0 * 2.0       # ckpt save+restore
        logits = tokens_local * (V // tp) * 4.0 * 2.0
        return w + opt + act + logits
    if shape.mode == "prefill":
        w = active_params * 2.0
        act = L * tokens_local * D * 2.0
        kv = L * tokens_local * cfg.n_kv_heads * cfg.head_dim * 2 * 2.0
        return w + act + kv
    # decode
    w = total_params * 2.0 / chips
    kv_len = min(S, cfg.sliding_window) if cfg.sliding_window else S
    kv = (L * tokens_local * kv_len * cfg.n_kv_heads * cfg.head_dim
          * 2 * 2.0 / tp)
    logits = tokens_local * (V // tp) * 4.0
    return w + kv + logits


def analyze(compiled, cfg: ArchConfig, shape: ShapeCell, mesh_name: str,
            chips: int, model_flops: float,
            hlo_text: Optional[str] = None,
            total_params: Optional[int] = None,
            active_params: Optional[int] = None) -> RooflineReport:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    cost_flops = float(cost.get("flops", 0.0))
    cost_bytes = float(cost.get("bytes accessed", 0.0))

    mem = compiled.memory_analysis()
    peak = 0.0
    if mem is not None:
        for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                     "output_size_in_bytes"):
            peak += float(getattr(mem, attr, 0) or 0)
        peak -= float(getattr(mem, "alias_size_in_bytes", 0) or 0)

    text = hlo_text if hlo_text is not None else compiled.as_text()
    coll = hlo_mod.collective_bytes(text)
    coll_dev = coll.total_bytes
    dot_flops_dev = hlo_mod.hlo_dot_flops(text)

    tot = total_params if total_params is not None else 0
    act = active_params if active_params is not None else tot
    analytic_dev = analytic_traffic(cfg, shape, chips, tot or act, act)

    hlo_global = dot_flops_dev * chips
    compute_s = hlo_global / (chips * PEAK_FLOPS)
    memory_s = analytic_dev / HBM_BW
    memory_raw_s = cost_bytes / HBM_BW
    collective_s = coll_dev / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    step_time = max(terms.values()) or 1e-12
    useful = model_flops / hlo_global if hlo_global > 0 else 0.0
    useful_compute_s = model_flops / (chips * PEAK_FLOPS)
    return RooflineReport(
        arch=cfg.name, shape=shape.name, mesh=mesh_name, chips=chips,
        cost_flops_per_device=cost_flops, cost_bytes_per_device=cost_bytes,
        dot_flops_per_device=dot_flops_dev,
        coll_bytes_per_device=coll_dev,
        analytic_bytes_per_device=analytic_dev,
        peak_memory_per_device=peak,
        compute_s=compute_s, memory_s=memory_s, memory_raw_s=memory_raw_s,
        collective_s=collective_s,
        bottleneck=bottleneck,
        model_flops=model_flops, hlo_global_flops=hlo_global,
        useful_ratio=useful,
        roofline_fraction=useful_compute_s / step_time,
        fits_hbm=peak <= HBM_PER_CHIP,
    )


def format_report(r: RooflineReport) -> str:
    return (f"{r.arch:22s} {r.shape:12s} {r.mesh:10s} "
            f"comp={r.compute_s*1e3:9.3f}ms mem={r.memory_s*1e3:9.3f}ms "
            f"coll={r.collective_s*1e3:9.3f}ms -> {r.bottleneck:10s} "
            f"useful={r.useful_ratio:6.3f} frac={r.roofline_fraction:6.3f} "
            f"peakmem={r.peak_memory_per_device/1e9:7.2f}GB "
            f"{'FITS' if r.fits_hbm else 'OVER'}")
