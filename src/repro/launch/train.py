"""Training launcher.

On a real fleet this process runs per host under the cluster scheduler
(GKE/xmanager); jax.distributed handles cross-host init. On the CPU CI
image it drives the same code path single-host with a reduced config:

    PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --reduced \
        --steps 50 --ckpt-dir /tmp/ck

Fault tolerance: re-running the same command after a kill resumes from
the latest complete checkpoint (exact data + optimizer + energy-ledger
state). Energy telemetry: every run logs naive and corrected J/step from
the calibrated sensor model (the paper's contribution, applied).
"""
from __future__ import annotations

import argparse

from repro.configs.base import ShapeCell, get_shape
from repro.configs.registry import ARCH_IDS, get_config
from repro.optim.adamw import AdamWConfig
from repro.train.loop import LoopConfig, run_training
from repro.train.step import TrainConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b", choices=ARCH_IDS)
    ap.add_argument("--shape", default="")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--sensor", default="tpu_v5e_chip")
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced)
    shape = get_shape(args.shape) if args.shape else ShapeCell(
        "cli", args.seq_len, args.batch, "train")
    tcfg = TrainConfig(
        microbatches=args.microbatches,
        compress_grads=args.compress_grads,
        optim=AdamWConfig(lr_peak=args.lr, warmup_steps=max(args.steps // 10, 1),
                          total_steps=args.steps))
    lcfg = LoopConfig(total_steps=args.steps, ckpt_every=args.ckpt_every,
                      sensor_profile=args.sensor)
    out = run_training(cfg, shape, tcfg, lcfg,
                       ckpt_dir=args.ckpt_dir or None)
    print("final_loss:", out["final_loss"])
    print("stragglers:", out["stragglers"])
    print("energy:", out["energy"])


if __name__ == "__main__":
    main()
