"""Post-optimisation HLO analysis: collective bytes + loop trip counts.

``cost_analysis()`` does not report collective traffic, so the roofline's
third term comes from parsing ``compiled.as_text()``: every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
instruction contributes its operand bytes; instructions inside a while
body (the layer scan) are scaled by the loop trip count, recovered from
the loop-bound constant in the while condition.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

import numpy as np

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_TYPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)$")
# header lines look like `%name (args...) -> type {` — args may contain
# nested parens (tuple types), so anchor on the trailing `-> ... {`
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*->.*\{\s*$")
_WHILE_RE = re.compile(
    r"while\(.*?\).*?condition=%?([\w\.\-]+).*?body=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"[su]\d+\[\]\s+constant\((\d+)\)")


def _type_bytes(type_str: str) -> int:
    """Bytes of one HLO type expression (handles tuples by summing)."""
    total = 0
    for m in _TYPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, float]
    count_by_kind: Dict[str, int]

    @property
    def total_bytes(self) -> float:
        return float(sum(self.bytes_by_kind.values()))


def _split_computations(hlo: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    cur: Optional[str] = None
    for line in hlo.splitlines():
        m = _COMP_HDR_RE.match(line.strip())
        if m and "{" in line:
            cur = m.group(1)
            comps[cur] = []
        elif cur is not None:
            if line.strip() == "}":
                cur = None
            else:
                comps[cur].append(line)
    return comps


def _trip_count(cond_lines: List[str]) -> int:
    """Loop bound = the largest scalar-int constant in the condition."""
    best = 1
    for line in cond_lines:
        for m in _CONST_RE.finditer(line):
            best = max(best, int(m.group(1)))
    return best


def collective_bytes(hlo_text: str) -> CollectiveStats:
    comps = _split_computations(hlo_text)

    # computation -> trip multiplier (while bodies run trip_count times)
    multiplier: Dict[str, float] = {name: 1.0 for name in comps}
    for name, lines in comps.items():
        for line in lines:
            m = _WHILE_RE.search(line)
            if m:
                cond, body = m.group(1), m.group(2)
                trips = _trip_count(comps.get(cond, []))
                for target in (body, cond):
                    if target in multiplier:
                        multiplier[target] = max(multiplier[target],
                                                 float(trips) * multiplier[name])

    bytes_by_kind: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    count_by_kind: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for name, lines in comps.items():
        mult = multiplier.get(name, 1.0)
        for line in lines:
            ls = line.strip()
            m = _INSTR_RE.match(ls)
            if not m:
                continue
            rest = m.group(2)
            for kind in _COLLECTIVES:
                # plain or async-start only; `-done` would double-count
                km = re.match(rf"(.+?)\s{re.escape(kind)}(-start)?\(", rest)
                if km:
                    b = _type_bytes(km.group(1))
                    if km.group(2):          # -start type is (in, out) tuple
                        b /= 2.0
                    bytes_by_kind[kind] += b * mult
                    count_by_kind[kind] += int(mult)
                    break
    return CollectiveStats(bytes_by_kind, count_by_kind)


# ---------------------------------------------------------------------------
# Dot-FLOPs with loop trip counts (XLA cost_analysis counts a while body
# once; matmul FLOPs are what MFU accounting uses anyway)
# ---------------------------------------------------------------------------

_DOT_RE = re.compile(
    r"^(.+?)\s+dot\(([^)]*)\).*?lhs_contracting_dims=\{([\d,]*)\}")


def _shape_of(type_str: str) -> Tuple[str, Tuple[int, ...]]:
    m = _TYPE_RE.search(type_str)
    if not m:
        return "", ()
    dims = tuple(int(d) for d in m.group(2).split(",") if d)
    return m.group(1), dims


def hlo_dot_flops(hlo_text: str) -> float:
    """Σ over dot instructions of 2·prod(out_shape)·prod(K dims),
    with while-body instructions scaled by recovered trip counts."""
    comps = _split_computations(hlo_text)

    multiplier: Dict[str, float] = {name: 1.0 for name in comps}
    for name, lines in comps.items():
        for line in lines:
            m = _WHILE_RE.search(line)
            if m:
                cond, body = m.group(1), m.group(2)
                trips = _trip_count(comps.get(cond, []))
                for target in (body, cond):
                    if target in multiplier:
                        multiplier[target] = max(
                            multiplier[target],
                            float(trips) * multiplier[name])

    total = 0.0
    for name, lines in comps.items():
        mult = multiplier.get(name, 1.0)
        # local name -> type map (defs precede uses)
        types: Dict[str, str] = {}
        for line in lines:
            m = _INSTR_RE.match(line.strip())
            if m:
                types[m.group(1)] = m.group(2)
        for line in lines:
            m = _INSTR_RE.match(line.strip())
            if not m:
                continue
            dm = _DOT_RE.match(m.group(2))
            if not dm:
                continue
            out_t, operands, lhs_cd = dm.group(1), dm.group(2), dm.group(3)
            _, out_shape = _shape_of(out_t)
            lhs_name = operands.split(",")[0].strip().lstrip("%")
            lhs_t = types.get(lhs_name, "")
            _, lhs_shape = _shape_of(lhs_t)
            k = 1
            for d in lhs_cd.split(","):
                if d and lhs_shape:
                    idx = int(d)
                    if idx < len(lhs_shape):
                        k *= lhs_shape[idx]
            flops = 2.0 * float(np.prod(out_shape)) * float(k) if out_shape \
                else 0.0
            total += flops * mult
    return total


def count_op(hlo_text: str, opname: str) -> int:
    return len(re.findall(rf"\s{re.escape(opname)}\(", hlo_text))
