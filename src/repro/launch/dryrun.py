import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_EXTRA_XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count="
                           + os.environ.get("REPRO_DRYRUN_DEVICES", "512")
                           ).strip()

# Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.
#
# MUST be run as its own process (`python -m repro.launch.dryrun ...`): the
# XLA_FLAGS line above executes before any jax import, giving the CPU host
# 512 placeholder devices so the production meshes build.
#
# Per cell it produces: compiled.memory_analysis() (fits?),
# compiled.cost_analysis() (FLOPs/bytes), parsed collective bytes, and the
# three-term roofline — written as JSON artifacts consumed by
# EXPERIMENTS.md §Dry-run/§Roofline and benchmarks/roofline_report.py.

import argparse                      # noqa: E402
import json                          # noqa: E402
import sys                           # noqa: E402
import time                          # noqa: E402
import traceback                     # noqa: E402

import jax                           # noqa: E402
import numpy as np                   # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs.base import (SHAPES, ShapeCell, cell_applicable,  # noqa: E402
                                get_shape)
from repro.configs.registry import ARCH_IDS, get_config  # noqa: E402
from repro.distributed.act_shard import activation_sharding  # noqa: E402
from repro.distributed.sharding import ShardingRules, tree_shardings  # noqa: E402
from repro.launch import roofline as roofline_mod  # noqa: E402
from repro.launch.mesh import (make_mesh, make_production_mesh,  # noqa: E402
                               n_chips, require_devices)
from repro.models import api, transformer  # noqa: E402
from repro.optim import adamw  # noqa: E402
from repro.train.step import (TrainConfig, make_decode_step,  # noqa: E402
                              make_train_step)


def pick_layout(cfg, shape, n_devices: int) -> str:
    """auto layout: small models don't benefit from 16-way TP for
    train/prefill — both axes go to data/FSDP (§Perf iteration O2) — but
    only when the global batch divides the full device count (otherwise
    the batch can't shard that wide and GSPMD degenerates)."""
    if shape.mode == "decode":
        return "default"
    if shape.global_batch % n_devices != 0:
        return "default"
    active = (transformer.active_param_count(cfg) if not cfg.encdec
              else cfg.d_model * cfg.d_model * 12 * cfg.n_layers)
    return "fsdp_only" if active < 4e9 else "default"


def lower_cell(cfg, shape: ShapeCell, mesh, *, reduced: bool = False,
               constrain_acts: bool = True, layout: str = "auto"):
    """Lower + compile one (arch × shape) cell on the given mesh.

    Returns (compiled, hlo_text, lower_s, compile_s).
    """
    if layout == "auto":
        layout = pick_layout(cfg, shape, n_chips(mesh))
    rules = ShardingRules(mesh, layout=layout)
    bsz = int(np.prod([rules.axis_sizes[a] for a in rules.batch_axes])) \
        if rules.batch_axes else 1
    tp_size = (rules.axis_sizes[rules.tp_axis]
               if rules.layout == "default" else 1)
    ctx = (activation_sharding(rules.batch_axes,
                               rules.tp_axis if rules.layout == "default"
                               else "", tp_size, batch_size=bsz)
           if constrain_acts else _nullctx())
    pspecs = api.param_specs(cfg)
    params_sh = tree_shardings(rules, pspecs, "params")
    inputs = api.input_specs(cfg, shape)
    inputs_sh = tree_shardings(rules, inputs, "inputs")
    repl = NamedSharding(mesh, P())

    if shape.mode == "train":
        remat_policy = os.environ.get("REPRO_REMAT_POLICY", "full")
        tcfg = TrainConfig(remat=True, remat_policy=remat_policy)
        opt_specs = adamw.state_specs(pspecs)
        # count replicated; mu/nu shard like params (ZeRO-3)
        opt_sh = adamw.AdamWState(
            count=repl,
            mu=tree_shardings(rules, pspecs, "params"),
            nu=tree_shardings(rules, pspecs, "params"))
        step = make_train_step(cfg, tcfg)
        jitted = jax.jit(step,
                         in_shardings=(params_sh, opt_sh, inputs_sh),
                         out_shardings=(params_sh, opt_sh, repl))
        with mesh, ctx:
            t0 = time.perf_counter()
            lowered = jitted.lower(pspecs, opt_specs, inputs)
            t1 = time.perf_counter()
            compiled = lowered.compile()
            t2 = time.perf_counter()
    else:
        # prefill lowers the forward pass; decode lowers serve_step
        if shape.mode == "prefill":
            def fwd(params, batch):
                logits, aux = api.forward(params, cfg, batch, remat=True)
                return logits
            batch_ok = shape.global_batch % int(
                np.prod([rules.axis_sizes[a]
                         for a in rules.batch_axes])) == 0
            out_sh = NamedSharding(
                mesh, P(rules.batch_axes if batch_ok else None, None,
                        rules._tp_if(cfg.vocab)))
            jitted = jax.jit(fwd, in_shardings=(params_sh, inputs_sh),
                             out_shardings=out_sh)
            args = (pspecs, inputs)
        else:
            # decode layout: weights stationary, batch activations
            # replicated (ShardingRules.replicate_batch docstring)
            rules_dec = ShardingRules(mesh, replicate_batch=True)
            ctx = (activation_sharding(
                rules_dec.batch_axes, rules_dec.tp_axis,
                rules_dec.axis_sizes[rules_dec.tp_axis], batch_size=1,
                fsdp_axis=rules_dec.fsdp_axis,
                fsdp_size=rules_dec.axis_sizes[rules_dec.fsdp_axis],
                mode="decode")
                if constrain_acts else _nullctx())
            inputs_sh = tree_shardings(rules_dec, inputs, "inputs")
            cache = api.cache_specs(cfg, shape.global_batch, shape.seq_len)
            cache_sh = tree_shardings(rules, cache, "cache")
            step = make_decode_step(cfg)
            jitted = jax.jit(step,
                             in_shardings=(params_sh, cache_sh, inputs_sh),
                             out_shardings=(repl, cache_sh))
            args = (pspecs, cache, inputs)
        with mesh, ctx:
            t0 = time.perf_counter()
            lowered = jitted.lower(*args)
            t1 = time.perf_counter()
            compiled = lowered.compile()
            t2 = time.perf_counter()

    hlo_text = compiled.as_text()
    return compiled, hlo_text, (t1 - t0), (t2 - t1)


import contextlib


def _nullctx():
    return contextlib.nullcontext()


def run_cell(arch_id: str, shape_name: str, mesh, mesh_name: str,
             reduced: bool, outdir: str | None):
    cfg = get_config(arch_id, reduced=reduced)
    shape = get_shape(shape_name)
    ok, reason = cell_applicable(cfg, shape)
    if not ok:
        print(f"SKIP  {arch_id:24s} {shape_name:12s} {mesh_name:10s} {reason}")
        return {"arch": arch_id, "shape": shape_name, "mesh": mesh_name,
                "status": "skip", "reason": reason}
    try:
        compiled, hlo_text, lower_s, compile_s = lower_cell(
            cfg, shape, mesh, reduced=reduced)
    except Exception as e:  # noqa: BLE001 — report, continue sweep
        traceback.print_exc()
        print(f"FAIL  {arch_id:24s} {shape_name:12s} {mesh_name}: {e}")
        return {"arch": arch_id, "shape": shape_name, "mesh": mesh_name,
                "status": "fail", "error": str(e)[:500]}

    chips = n_chips(mesh)
    active = (transformer.active_param_count(cfg) if not cfg.encdec
              else _encdec_active(cfg))
    total = transformer.param_count(cfg) if not cfg.encdec else \
        sum(int(np.prod(x.shape))
            for x in jax.tree_util.tree_leaves(api.param_specs(cfg)))
    mf = roofline_mod.model_flops_for(cfg, shape, active)
    report = roofline_mod.analyze(compiled, cfg, shape, mesh_name, chips,
                                  mf, hlo_text=hlo_text,
                                  total_params=total, active_params=active)
    mem = compiled.memory_analysis()
    print(f"OK    {roofline_mod.format_report(report)} "
          f"lower={lower_s:5.1f}s compile={compile_s:6.1f}s")
    result = {"arch": arch_id, "shape": shape_name, "mesh": mesh_name,
              "status": "ok", "lower_s": lower_s, "compile_s": compile_s,
              "roofline": report.to_dict(),
              "memory_analysis": _mem_dict(mem)}
    if outdir:
        os.makedirs(outdir, exist_ok=True)
        fn = os.path.join(outdir,
                          f"{arch_id}__{shape_name}__{mesh_name}.json")
        with open(fn, "w") as f:
            json.dump(result, f, indent=1)
    return result


def _encdec_active(cfg) -> int:
    total = 0
    for s in jax.tree_util.tree_leaves(api.param_specs(cfg)):
        total += int(np.prod(s.shape))
    emb = cfg.vocab * cfg.d_model
    return total - emb


def _mem_dict(mem) -> dict:
    out = {}
    for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                 "output_size_in_bytes", "generated_code_size_in_bytes",
                 "alias_size_in_bytes"):
        v = getattr(mem, attr, None)
        if v is not None:
            out[attr] = int(v)
    return out


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all", help="arch id or 'all'")
    ap.add_argument("--shape", default="all", help="shape name or 'all'")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both", "tiny"])
    ap.add_argument("--reduced", action="store_true",
                    help="use reduced configs (CI smoke of the dry-run path)")
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()

    arch_ids = list(ARCH_IDS) if args.arch == "all" else [args.arch]
    shape_names = [s.name for s in SHAPES] if args.shape == "all" \
        else [args.shape]

    meshes = []
    if args.mesh in ("single", "both"):
        require_devices(256)
        meshes.append((make_production_mesh(multi_pod=False), "pod16x16"))
    if args.mesh in ("multi", "both"):
        require_devices(512)
        meshes.append((make_production_mesh(multi_pod=True), "pod2x16x16"))
    if args.mesh == "tiny":
        meshes.append((make_mesh((2, 2), ("data", "model")), "tiny2x2"))

    results = []
    for mesh, mesh_name in meshes:
        for arch_id in arch_ids:
            for shape_name in shape_names:
                results.append(run_cell(arch_id, shape_name, mesh,
                                        mesh_name, args.reduced, args.out))
    n_fail = sum(1 for r in results if r["status"] == "fail")
    n_ok = sum(1 for r in results if r["status"] == "ok")
    n_skip = sum(1 for r in results if r["status"] == "skip")
    print(f"\ndry-run: {n_ok} ok, {n_skip} skip, {n_fail} fail")
    return 1 if n_fail else 0


if __name__ == "__main__":
    sys.exit(main())
