"""Production mesh construction (see brief: MULTI-POD DRY-RUN §1).

``make_production_mesh`` is a FUNCTION so importing this module never
touches jax device state.  The dry-run entrypoint sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; everything else (tests, benches) sees the real single device.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Sequence[int], axes: Optional[Sequence[str]] = None):
    """Arbitrary mesh for tests (e.g. (2,2) on 4 forced host devices)."""
    shape = tuple(shape)
    if axes is None:
        axes = ("pod", "data", "model")[-len(shape):] if len(shape) <= 3 \
            else tuple(f"ax{i}" for i in range(len(shape)))
    return jax.make_mesh(shape, tuple(axes))


def data_mesh(n_shards: Optional[int] = None):
    """1-D ``("data",)`` mesh over the first ``n_shards`` local devices —
    the fleet-audit sharding axis (see ``core/fleet_engine_shard``).

    Unlike :func:`make_mesh` this may use a *subset* of the visible
    devices, so a 4-way audit mesh works on an 8-device host.  Defaults
    to every visible device.  On CPU hosts, set
    ``XLA_FLAGS=--xla_force_host_platform_device_count=<n>`` before the
    first jax import to expose n devices (``docs/scaling.md``)."""
    n = jax.device_count() if n_shards is None else int(n_shards)
    if n < 1:
        raise ValueError(f"n_shards must be >= 1, got {n}")
    require_devices(n)
    devs = np.asarray(jax.devices()[:n], dtype=object)
    return jax.sharding.Mesh(devs, ("data",))


def n_chips(mesh) -> int:
    return int(np.prod(mesh.devices.shape))


def require_devices(n: int) -> None:
    have = jax.device_count()
    if have < n:
        raise RuntimeError(
            f"mesh needs {n} devices but the backend exposes {have}. "
            "The dry-run entrypoint must set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=<n> before "
            "any jax import (see launch/dryrun.py).")
