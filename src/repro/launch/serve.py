"""Serving launcher: batched decode with slot-based continuous batching.

    PYTHONPATH=src python -m repro.launch.serve --arch olmo-1b --reduced \
        --requests 8 --new-tokens 16
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs.registry import ARCH_IDS, get_config
from repro.models import api
from repro.serve.engine import Request, ServingEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b", choices=ARCH_IDS)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced)
    if cfg.encdec or cfg.input_mode == "embeds":
        raise SystemExit("CLI serving demo targets token-LM archs")
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(cfg, params, n_slots=args.slots,
                        max_seq=args.max_seq)
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(1, cfg.vocab, size=8).astype(np.int32),
                    max_new_tokens=args.new_tokens)
            for i in range(args.requests)]
    t0 = time.perf_counter()
    for r in reqs:
        eng.submit(r)
    eng.run(max_ticks=10_000)
    dt = time.perf_counter() - t0
    done = sum(r.done for r in reqs)
    toks = sum(len(r.generated) for r in reqs)
    print(f"served {done}/{len(reqs)} requests, {toks} tokens "
          f"in {dt:.2f}s ({toks/dt:.1f} tok/s), {eng.ticks} ticks")
    for r in reqs[:3]:
        print(f"  req{r.request_id}: {list(r.prompt)} -> {r.generated}")


if __name__ == "__main__":
    main()
